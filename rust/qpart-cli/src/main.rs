//! `qpart` — launcher for the QPART serving stack.
//!
//! ```text
//! qpart serve    [--config cfg.json] [--set k=v ...] [--listen addr] [--artifacts dir]
//! qpart request  --model mlp6 [--accuracy 0.01] [--n 16] [--addr host:port]
//!                [--capacity-bps 2e8] [--clock-hz 2e8] [--artifacts dir]
//! qpart sim      [--model mlp6] [--rate 20] [--devices 16] [--duration 10] [--seed 1]
//! qpart offline  [--model mlp6] [--artifacts dir]
//! qpart models   [--artifacts dir]
//! ```
//!
//! `serve` starts the coordinator; `request` plays an edge device over the
//! two-phase protocol (real PJRT execution on both sides); `sim` runs the
//! discrete-event fleet simulation; `offline` prints the Algorithm-1
//! pattern table; `models` lists the bundle.

mod args;

use args::Args;
use qpart::prelude::*;
use qpart::coordinator::client::{paper_request, random_input};
use std::rc::Rc;

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(raw) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    };
    std::process::exit(code);
}

fn run(raw: Vec<String>) -> Result<(), String> {
    let args = Args::parse(raw)?;
    match args.positional.first().map(String::as_str) {
        Some("serve") => cmd_serve(&args),
        Some("request") => cmd_request(&args),
        Some("sim") => cmd_sim(&args),
        Some("offline") => cmd_offline(&args),
        Some("models") => cmd_models(&args),
        Some(other) => Err(format!("unknown command '{other}'\n{USAGE}")),
        None => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

const USAGE: &str = "usage: qpart <serve|request|sim|offline|models> [flags]\n\
  serve    --listen 127.0.0.1:7878 --artifacts artifacts [--config f] [--set k=v]\n\
           [--workers N]   executor-pool size: N inference threads, each owning\n\
                           its own PJRT executor (default: serving.workers = 4;\n\
                           mirrors the simulator's server_slots)\n\
           [--queue N]     admission control: bounded job-queue depth; requests\n\
                           beyond it are shed with an 'overloaded' error\n\
                           (default: serving.queue_capacity = 1024)\n\
           [--sessions N]  two-phase session-table capacity, sharded across\n\
                           workers; oldest evicted first (default: 4096)\n\
  request  --model mlp6 --accuracy 0.01 --n 16 --addr 127.0.0.1:7878\n\
  sim      --model mlp6 --rate 20 --devices 16 --duration 10\n\
  offline  --model mlp6\n\
  models";

fn load_config(args: &Args) -> Result<Config, String> {
    let mut cfg = match args.get("config") {
        Some(path) => Config::from_file(path).map_err(|e| e.to_string())?,
        None => Config::defaults(),
    };
    for kv in args.get_all("set") {
        cfg.set_override(kv).map_err(|e| e.to_string())?;
    }
    Ok(cfg)
}

fn cmd_serve(args: &Args) -> Result<(), String> {
    let cfg = load_config(args)?;
    let serving = cfg.serving().map_err(|e| e.to_string())?;
    let server_cfg = qpart::coordinator::ServerConfig {
        listen: args.get_or("listen", &serving.listen).to_string(),
        workers: args.get_usize("workers", serving.workers)?,
        queue_capacity: args.get_usize("queue", serving.queue_capacity)?,
        session_capacity: args.get_usize("sessions", 4096)?,
        artifacts_dir: args.get_or("artifacts", &serving.artifacts_dir).to_string(),
    };
    println!(
        "loading bundle from '{}' ({} workers, queue {}) ...",
        server_cfg.artifacts_dir, server_cfg.workers, server_cfg.queue_capacity
    );
    let handle = serve(server_cfg)?;
    println!("qpart coordinator listening on {}", handle.addr);
    println!("(ctrl-c to stop)");
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_request(args: &Args) -> Result<(), String> {
    let artifacts = args.get_or("artifacts", "artifacts");
    let addr = args.get_or("addr", "127.0.0.1:7878");
    let model = args.get_or("model", "mlp6").to_string();
    let n = args.get_usize("n", 8)?;
    let accuracy = args.get_f64("accuracy", 0.01)?;
    let bundle = Rc::new(Bundle::load(artifacts).map_err(|e| e.to_string())?);
    let mut client =
        DeviceClient::connect(addr, Rc::clone(&bundle)).map_err(|e| e.to_string())?;

    let entry = bundle.model(&model).map_err(|e| e.to_string())?;
    let (x, y) = bundle.dataset(&entry.dataset).map_err(|e| e.to_string())?;
    let x = HostTensor::from(x);
    let arch = bundle.arch(&entry.arch).map_err(|e| e.to_string())?;

    let mut req = paper_request(&model, accuracy);
    req.channel_capacity_bps = args.get_f64("capacity-bps", req.channel_capacity_bps)?;
    req.clock_hz = args.get_f64("clock-hz", req.clock_hz)?;

    // --simulate: one-shot mode (server plays the device too)
    let simulate = args.has("simulate");
    let mut correct = 0usize;
    let t0 = std::time::Instant::now();
    for i in 0..n {
        let idx = i % x.batch();
        let input = x.slice_rows_padded(idx, idx + 1, 1);
        let (pred, partition) = if simulate {
            match client.simulate(req.clone(), &input).map_err(|e| e.to_string())? {
                qpart::proto::messages::Response::Result(r) => {
                    let p = r
                        .costs
                        .as_ref()
                        .and_then(|c| c.get("partition"))
                        .and_then(|v| v.as_i64())
                        .unwrap_or(-1);
                    (r.prediction, p as usize)
                }
                other => return Err(format!("unexpected response {other:?}")),
            }
        } else {
            let (pred, _logits, partition) =
                client.infer(req.clone(), input).map_err(|e| e.to_string())?;
            (pred, partition)
        };
        if pred == y[idx] {
            correct += 1;
        }
        println!("request {i}: partition={partition} pred={pred} label={}", y[idx]);
    }
    let dt = t0.elapsed();
    println!(
        "\n{n} requests in {:.2}s ({:.1} req/s), accuracy {}/{} = {:.1}%",
        dt.as_secs_f64(),
        n as f64 / dt.as_secs_f64(),
        correct,
        n,
        100.0 * correct as f64 / n as f64
    );
    // sanity: the arch accepts a random input of its declared shape
    let probe = random_input(arch, 7);
    debug_assert_eq!(probe.row_elems() as u64, arch.activation_elems(0));
    Ok(())
}

fn cmd_sim(args: &Args) -> Result<(), String> {
    let model_name = args.get_or("model", "mlp6");
    let arch = builtin(model_name).map_err(|e| e.to_string())?;
    let levels = [0.0025, 0.005, 0.01, 0.02, 0.05];
    // use the bundle calibration when available, else synthetic
    let artifacts = args.get_or("artifacts", "artifacts");
    let calib = Bundle::load(artifacts)
        .and_then(|b| b.calibration(model_name))
        .unwrap_or_else(|_| CalibrationTable::synthetic(&arch, &levels, 1));
    let patterns =
        offline_quantize(&arch, &calib, OfflineConfig::default()).map_err(|e| e.to_string())?;
    let cfg = FleetConfig {
        workload: WorkloadConfig {
            arrival_rate: args.get_f64("rate", 20.0)?,
            n_devices: args.get_usize("devices", 16)?,
            duration_s: args.get_f64("duration", 10.0)?,
            seed: args.get_usize("seed", 1)? as u64,
        },
        ..Default::default()
    };
    let report = run_fleet(&arch, &patterns, &DeviceClass::default_fleet(), &cfg)
        .map_err(|e| e.to_string())?;
    println!("{}", report.perf.to_json().to_string_pretty());
    println!(
        "rejected: {}, server cost: {:.4}, partitions: {:?}",
        report.rejected,
        report.server_cost,
        report.perf.partition_histogram(arch.num_layers())
    );
    Ok(())
}

fn cmd_offline(args: &Args) -> Result<(), String> {
    let model_name = args.get_or("model", "mlp6");
    let artifacts = args.get_or("artifacts", "artifacts");
    let (arch, calib) = match Bundle::load(artifacts) {
        Ok(b) => {
            let m = b.model(model_name).map_err(|e| e.to_string())?;
            let arch = b.arch(&m.arch).map_err(|e| e.to_string())?.clone();
            let calib = b.calibration(model_name).map_err(|e| e.to_string())?;
            (arch, calib)
        }
        Err(_) => {
            let arch = builtin(model_name).map_err(|e| e.to_string())?;
            let calib =
                CalibrationTable::synthetic(&arch, &[0.0025, 0.005, 0.01, 0.02, 0.05], 1);
            println!("(no artifacts bundle — using synthetic calibration)");
            (arch, calib)
        }
    };
    let set =
        offline_quantize(&arch, &calib, OfflineConfig::default()).map_err(|e| e.to_string())?;
    println!("offline pattern table for {model_name} (Algorithm 1):");
    for (k, row) in set.patterns.iter().enumerate() {
        println!("  accuracy level a={}", set.levels[k]);
        for pat in row {
            println!(
                "    p={:<2} bits={:?} b_x={} payload={} bits (f32: {}) predicted degradation {:.5}",
                pat.partition,
                pat.weight_bits,
                pat.activation_bits,
                pat.payload_bits(&arch),
                pat.payload_bits_f32(&arch),
                pat.predicted_degradation,
            );
        }
    }
    Ok(())
}

fn cmd_models(args: &Args) -> Result<(), String> {
    let artifacts = args.get_or("artifacts", "artifacts");
    let bundle = Bundle::load(artifacts).map_err(|e| e.to_string())?;
    println!("{:<20} {:<12} {:<14} {:>8} {:>12} {:>9}", "model", "arch", "dataset", "layers", "params", "test acc");
    for m in &bundle.models {
        let arch = bundle.arch(&m.arch).map_err(|e| e.to_string())?;
        println!(
            "{:<20} {:<12} {:<14} {:>8} {:>12} {:>8.2}%",
            m.name,
            m.arch,
            m.dataset,
            arch.num_layers(),
            arch.total_params(),
            m.test_accuracy * 100.0
        );
    }
    Ok(())
}
