//! Runtime error type.

/// Errors from artifact loading / PJRT execution.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// Propagated qpart-core error (JSON schema, tensor format, ...).
    #[error(transparent)]
    Core(#[from] qpart_core::Error),

    /// XLA / PJRT failure (compile or execute).
    #[error("xla error: {0}")]
    Xla(String),

    /// Requested executable is not in the bundle.
    #[error("no executable: {0}")]
    MissingExec(String),

    /// Model / dataset / arch not present in the manifest.
    #[error("not in bundle: {0}")]
    NotInBundle(String),

    /// Shape mismatch between artifacts and runtime inputs.
    #[error("shape mismatch: {0}")]
    Shape(String),

    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

pub type Result<T> = std::result::Result<T, Error>;

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}
