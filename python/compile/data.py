"""Synthetic datasets for the build-time pipeline.

This environment has no network access, so the paper's datasets (MNIST,
SVHN, CIFAR10/100, ImageNet) are replaced by deterministic procedural
stand-ins (DESIGN.md §3). What matters for reproducing the paper's claims
is that a *trained classifier with real decision boundaries* exhibits
layer-wise sensitivity to quantization noise — absolute dataset difficulty
does not enter the QPART math.

Two generators:

* :func:`synth_digits` — 28x28 grayscale, 10 classes (MNIST stand-in):
  class-specific stroke prototypes + elastic jitter + pixel noise.
* :func:`synth_images`  — 32x32x3, N classes (SVHN/CIFAR stand-ins):
  class-specific Gabor-like textures + color tint + noise.

Both are deterministic in (n, seed) and stream-safe: sample `i` of a given
seed is always the same regardless of `n`.
"""

from __future__ import annotations

import numpy as np


def _prototypes_digits(rng: np.random.Generator, classes: int = 10) -> np.ndarray:
    """Random smooth stroke prototypes, one 28x28 map per class."""
    protos = np.zeros((classes, 28, 28), dtype=np.float32)
    yy, xx = np.mgrid[0:28, 0:28].astype(np.float32) / 27.0
    for c in range(classes):
        img = np.zeros((28, 28), dtype=np.float32)
        # 3 random "strokes": gaussian ridges along random quadratic curves
        for _ in range(3):
            a, b, d = rng.uniform(-2, 2, size=3)
            width = rng.uniform(0.03, 0.08)
            curve = a * (xx - 0.5) ** 2 + b * (xx - 0.5) + 0.5 + 0.15 * d
            img += np.exp(-((yy - curve) ** 2) / (2 * width**2))
        protos[c] = img / max(img.max(), 1e-6)
    return protos


def synth_digits(n: int, seed: int = 0, classes: int = 10, proto_seed: int = 77):
    """MNIST stand-in: returns (x[n,784] float32 in [0,1], y[n] int32).

    `proto_seed` fixes the class prototypes (the "task"); `seed` only drives
    sample-level randomness, so different splits share one distribution.
    """
    proto_rng = np.random.default_rng(proto_seed + 10_000)
    protos = _prototypes_digits(proto_rng, classes)
    rng = np.random.default_rng(seed)
    y = rng.integers(0, classes, size=n).astype(np.int32)
    x = np.empty((n, 28 * 28), dtype=np.float32)
    # difficulty tuned so a trained mlp6 lands around the paper's ~96%
    # MNIST accuracy (not saturated: degradation experiments need headroom)
    shifts = rng.integers(-3, 4, size=(n, 2))
    noise = rng.normal(0.0, 0.30, size=(n, 28, 28)).astype(np.float32)
    scale = rng.uniform(0.6, 1.3, size=n).astype(np.float32)
    for i in range(n):
        img = np.roll(protos[y[i]], tuple(shifts[i]), axis=(0, 1)) * scale[i]
        img = np.clip(img + noise[i], 0.0, 1.0)
        x[i] = img.reshape(-1)
    return x, y


def _prototypes_images(rng: np.random.Generator, classes: int, side: int = 32) -> np.ndarray:
    """Class textures: sum of oriented sinusoids + color tint, (C,3,side,side)."""
    protos = np.zeros((classes, 3, side, side), dtype=np.float32)
    yy, xx = np.mgrid[0:side, 0:side].astype(np.float32) / (side - 1)
    for c in range(classes):
        tex = np.zeros((side, side), dtype=np.float32)
        for _ in range(4):
            fx, fy = rng.uniform(1.0, 6.0, size=2)
            phase = rng.uniform(0, 2 * np.pi)
            tex += np.sin(2 * np.pi * (fx * xx + fy * yy) + phase)
        tex = (tex - tex.min()) / max(float(np.ptp(tex)), 1e-6)
        tint = rng.uniform(0.3, 1.0, size=3).astype(np.float32)
        protos[c] = tint[:, None, None] * tex[None]
    return protos


def synth_images(n: int, classes: int, seed: int = 0, side: int = 32, proto_seed: int = 77):
    """SVHN/CIFAR stand-in: returns (x[n,3,side,side] float32, y[n] int32).

    `proto_seed` fixes the class textures; `seed` drives per-sample noise.
    """
    proto_rng = np.random.default_rng(proto_seed + 20_000)
    protos = _prototypes_images(proto_rng, classes, side)
    rng = np.random.default_rng(seed)
    y = rng.integers(0, classes, size=n).astype(np.int32)
    shifts = rng.integers(-4, 5, size=(n, 2))
    noise = rng.normal(0.0, 0.22, size=(n, 3, side, side)).astype(np.float32)
    scale = rng.uniform(0.6, 1.3, size=n).astype(np.float32)
    x = np.empty((n, 3, side, side), dtype=np.float32)
    for i in range(n):
        img = np.roll(protos[y[i]], tuple(shifts[i]), axis=(1, 2)) * scale[i]
        x[i] = np.clip(img + noise[i], 0.0, 1.0)
    return x, y


DATASETS = {
    # name -> (generator kwargs, input kind)
    "digits": dict(kind="digits", classes=10),
    "svhn_syn": dict(kind="images", classes=10),
    "cifar10_syn": dict(kind="images", classes=10),
    "cifar100_syn": dict(kind="images", classes=100),
    "imagenet_syn": dict(kind="images", classes=10),
}


def make(name: str, n: int, seed: int = 0):
    """Generate dataset `name` (see DATASETS). The prototype seed is salted
    per dataset name (so svhn_syn and cifar10_syn are different tasks);
    `seed` selects the split (train/test/calibration)."""
    meta = DATASETS[name]
    salt = sum(ord(ch) * (i + 1) for i, ch in enumerate(name))
    if meta["kind"] == "digits":
        return synth_digits(n, seed=seed + salt, classes=meta["classes"], proto_seed=salt)
    return synth_images(n, classes=meta["classes"], seed=seed + salt, proto_seed=salt)
