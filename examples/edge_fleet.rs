//! End-to-end serving driver (the repo's headline validation run; see
//! EXPERIMENTS.md §End-to-End).
//!
//! ```text
//! cargo run --release --example edge_fleet [-- <n_requests_per_class>]
//! ```
//!
//! Starts the **real coordinator** (TCP, PJRT, Algorithm 1 at startup) in
//! this process, then drives it with a heterogeneous simulated edge fleet
//! (phone / camera / watch — the paper's §I device diversity) over the
//! two-phase wire protocol. Every request really ships a bit-packed
//! quantized segment, really runs the Pallas-kernel executables on the
//! "device", and really finishes on the server. Reports per-class
//! latency, throughput, accuracy, partition choices, and the modeled
//! Eq. 17 costs; finishes with the discrete-event fleet simulation for
//! the long-horizon dynamics.

use qpart::coordinator::client::paper_request;
use qpart::prelude::*;
use qpart::sim::perf::Summary;
use std::sync::Arc;

struct ClassSpec {
    name: &'static str,
    clock_hz: f64,
    capacity_bps: f64,
    accuracy_budget: f64,
    /// Eq. 17 weights (ω, τ, η); None = paper defaults. A large η makes
    /// server billing dominant, pushing the optimizer toward on-device
    /// execution (large p) — the other end of the workload balance.
    weights: Option<(f64, f64, f64)>,
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let n_per_class: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(16);
    if Bundle::load("artifacts").is_err() {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        return Ok(());
    }

    // ---- start the real coordinator
    let handle = serve(qpart::coordinator::ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers: 4,
        queue_capacity: 256,
        session_capacity: 4096,
        artifacts_dir: "artifacts".into(),
        ..Default::default()
    })?;
    let addr = handle.addr.to_string();
    println!("coordinator up on {addr} (Algorithm 1 tables built at startup, 4 workers)");

    let bundle = Arc::new(Bundle::load("artifacts")?);
    let (x, y) = bundle.dataset("digits")?;
    let x = HostTensor::from(x);

    let classes = [
        ClassSpec {
            name: "phone  ",
            clock_hz: 2e9,
            capacity_bps: 200e6,
            accuracy_budget: 0.005,
            weights: None,
        },
        ClassSpec {
            name: "camera ",
            clock_hz: 400e6,
            capacity_bps: 50e6,
            accuracy_budget: 0.01,
            weights: None,
        },
        ClassSpec {
            name: "watch  ",
            clock_hz: 100e6,
            capacity_bps: 5e6,
            accuracy_budget: 0.05,
            weights: None,
        },
        // billing-sensitive gateway: η ≫ 1 → prefers on-device compute
        ClassSpec {
            name: "gateway",
            clock_hz: 1e9,
            capacity_bps: 200e6,
            accuracy_budget: 0.02,
            weights: Some((1.0, 1.0, 1e6)),
        },
    ];

    println!("\n=== live two-phase serving: {n_per_class} requests/class ===");
    let mut total_reqs = 0usize;
    let mut total_correct = 0usize;
    let t_all = std::time::Instant::now();
    for class in &classes {
        let mut client = DeviceClient::connect(&addr, Arc::clone(&bundle))?;
        let mut req = paper_request("mlp6", class.accuracy_budget);
        req.clock_hz = class.clock_hz;
        req.channel_capacity_bps = class.capacity_bps;
        req.weights = class.weights;

        let mut latencies = Vec::new();
        let mut correct = 0usize;
        let mut partitions = vec![0usize; 8];
        let t_class = std::time::Instant::now();
        for i in 0..n_per_class {
            let idx = (total_reqs + i) % x.batch();
            let input = x.slice_rows_padded(idx, idx + 1, 1);
            let t0 = std::time::Instant::now();
            let (pred, _logits, partition) = client.infer(req.clone(), input)?;
            latencies.push(t0.elapsed().as_secs_f64());
            partitions[partition.min(7)] += 1;
            if pred == y[idx] {
                correct += 1;
            }
        }
        let lat = Summary::of(&latencies);
        println!(
            "{} budget {:>5.2}% | {:>5.1} req/s | lat p50 {:>6.2} ms p99 {:>6.2} ms | \
             acc {:>5.1}% | partitions {:?}",
            class.name,
            class.accuracy_budget * 100.0,
            n_per_class as f64 / t_class.elapsed().as_secs_f64(),
            lat.p50 * 1e3,
            lat.p99 * 1e3,
            100.0 * correct as f64 / n_per_class as f64,
            &partitions[..7],
        );
        total_reqs += n_per_class;
        total_correct += correct;
    }
    println!(
        "TOTAL: {} requests in {:.2}s → {:.1} req/s end-to-end, accuracy {:.1}%",
        total_reqs,
        t_all.elapsed().as_secs_f64(),
        total_reqs as f64 / t_all.elapsed().as_secs_f64(),
        100.0 * total_correct as f64 / total_reqs as f64
    );
    let snap = handle.snapshot();
    println!(
        "coordinator metrics: {} requests, {} errors, {} sessions, handle mean {:.0} µs",
        snap.requests_total, snap.errors_total, snap.sessions_opened, snap.handle_mean_us
    );

    // ---- long-horizon dynamics via the discrete-event simulator
    println!("\n=== discrete-event fleet simulation (modeled costs, 60 s, 32 devices) ===");
    let arch = bundle.arch("mlp6")?.clone();
    let calib = bundle.calibration("mlp6")?;
    let patterns = offline_quantize(&arch, &calib, OfflineConfig::default())?;
    let cfg = FleetConfig {
        workload: WorkloadConfig {
            arrival_rate: 50.0,
            n_devices: 32,
            duration_s: 60.0,
            seed: 7,
        },
        ..Default::default()
    };
    let report = run_fleet(&arch, &patterns, &DeviceClass::default_fleet(), &cfg)?;
    let lat = report.perf.latency();
    println!(
        "{} requests | modeled latency p50 {:.2} ms p99 {:.2} ms | energy mean {:.3} mJ | \
         payload mean {:.0} KiB | server cost {:.4} | rejected {}",
        report.perf.records.len(),
        lat.p50 * 1e3,
        lat.p99 * 1e3,
        report.perf.energy().mean * 1e3,
        report.perf.payload().mean / 8.0 / 1024.0,
        report.server_cost,
        report.rejected
    );
    println!("partition histogram: {:?}", report.perf.partition_histogram(arch.num_layers()));

    handle.shutdown();
    Ok(())
}
