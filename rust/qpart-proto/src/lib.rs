//! # qpart-proto — the QPART wire protocol
//!
//! Wire protocol between edge devices and the QPART coordinator:
//! **newline-delimited JSON over TCP** (JSON-lines). This crate is the
//! protocol's single source of truth; `cargo doc -p qpart-proto` renders
//! this page as the protocol specification.
//!
//! ## Frame layout
//!
//! One message = one line:
//!
//! ```text
//! <UTF-8 JSON document, no embedded '\n'> '\n'
//! ```
//!
//! * Frames are read with [`read_frame`] / written with [`write_frame`].
//! * A trailing `'\r'` before the `'\n'` is tolerated and stripped.
//! * Frames larger than [`MAX_FRAME_BYTES`] (16 MiB) are rejected with
//!   `FrameError::TooLarge` — a full quantized mlp6 segment is well under
//!   1 MiB; the cap only guards against malformed or hostile peers.
//! * Non-UTF-8 frames are rejected (`FrameError::Utf8`).
//!
//! Every document is a JSON object whose `"type"` field tags the variant.
//! Unknown types are answered with an `error` response, not a dropped
//! connection.
//!
//! ## Binary payloads
//!
//! Bit-packed tensors (quantized weight/activation codes, see
//! `qpart_core::quant::pack_bits`) travel as **base64** strings (standard
//! alphabet, padded — [`base64::encode`]). A quantized tensor on the wire
//! is the triple of its grid header and packed codes:
//!
//! * `bits` — bit-width `b` (codes are `b`-bit grid indices, LSB-first
//!   packed into bytes),
//! * `qmin`, `step` — the uniform grid `value = qmin + code·step`,
//! * the base64 of the packed bytes (`ceil(n·b/8)` bytes for `n` codes).
//!
//! Raw f32 tensors (the `simulate` input) are base64 of their
//! little-endian bytes ([`messages::f32s_to_b64`]).
//!
//! ## Requests ([`messages::Request`])
//!
//! | `"type"`      | fields | meaning |
//! |---------------|--------|---------|
//! | `ping`        | — | liveness probe; answered with `pong` |
//! | `list_models` | — | enumerate served models; answered with `models` |
//! | `stats`       | — | metrics snapshot; answered with `stats` |
//! | `infer`       | [`messages::InferRequest`] fields | **phase 1**: open a session, answered with `segment` |
//! | `activation`  | `session`, `bits`, `qmin`, `step`, `dims`, `packed` | **phase 2**: upload the quantized boundary activation, answered with `result` |
//! | `simulate`    | `infer` fields + `input`, `input_dims` | one-shot: the server simulates the device too; answered with `result` |
//!
//! The `infer` request carries exactly the tuple of paper Algorithm 2's
//! Require line: model id, accuracy budget `a` (`accuracy_budget`),
//! channel capacity `r` (`channel_capacity_bps`), transmit power `π`
//! (`tx_power_w`), and the device compute profile: `f_local` (`clock_hz`),
//! `γ_local` (`cycles_per_mac`), `κ` (`kappa`), plus the device memory
//! capacity in bits (`memory_bits`) and optional objective weights
//! `[ω, τ, η]` (`weights`).
//!
//! Example (`infer`):
//!
//! ```json
//! {"type":"infer","model":"mlp6","accuracy_budget":0.01,
//!  "channel_capacity_bps":2e8,"tx_power_w":1.0,"clock_hz":2e8,
//!  "cycles_per_mac":5.0,"kappa":3e-27,"memory_bits":2147483648}
//! ```
//!
//! ## Responses ([`messages::Response`])
//!
//! | `"type"`  | fields | meaning |
//! |-----------|--------|---------|
//! | `pong`    | — | answer to `ping` |
//! | `models`  | `models`: array of `{name, arch, dataset, layers, params, test_accuracy}` | answer to `list_models` |
//! | `stats`   | `stats`: metrics document (aggregated over the executor pool, with a per-worker `workers` array) | answer to `stats` |
//! | `segment` | `session`, `model`, `pattern`, `layers` | **phase-1 answer**: the quantized, bit-packed model segment |
//! | `result`  | `session`, `prediction`, `logits`, `server_us`, optional `costs` | **phase-2 / simulate answer** |
//! | `error`   | `code`, `message` | any failure |
//!
//! In a `segment` response, `pattern` reports the chosen quantization
//! pattern (`partition`, per-layer `weight_bits`, `activation_bits`, the
//! offline `accuracy_level`, `predicted_degradation`, and the Eq. 17
//! `objective`), and `layers` is an array of [`messages::LayerBlob`]s —
//! per device-side layer: `layer` (1-based index), `bits`, `w_dims`,
//! weight grid (`w_qmin`, `w_step`) + base64 `w_packed`, and bias grid
//! (`b_qmin`, `b_step`, `b_len`) + base64 `b_packed`.
//!
//! Error `code`s the coordinator emits: `bad_frame`, `bad_request`,
//! `unknown_model`, `unknown_session`, `bad_activation`, `bad_input`,
//! `infeasible` (accuracy budget unreachable), `overloaded` (admission
//! control shed), `internal`, `shutdown`.
//!
//! ## Two-phase serving flow
//!
//! Mirroring Fig. 1/2 of the paper:
//!
//! 1. device → `infer` (model, accuracy budget, channel + compute profile)
//! 2. server → `segment` (the quantized, bit-packed model segment + the
//!    chosen pattern) — the downlink the paper's Eq. 14 charges for
//! 3. device runs layers `1..=p` locally, → `activation` (quantized,
//!    bit-packed boundary activation) — the uplink
//! 4. server finishes layers `p+1..=L`, → `result` (prediction + logits)
//!
//! `simulate` collapses 1–4 into one exchange for load generation: the
//! server plays both roles and reports the Eq. 17 cost breakdown in
//! `costs`.
//!
//! Sessions are server-side state keyed by the `session` id returned in
//! `segment`; they are consumed by the first `activation` referencing
//! them and evicted oldest-first under capacity pressure (an evicted
//! session answers `unknown_session`).

pub mod base64;
pub mod frame;
pub mod messages;

pub use frame::{read_frame, write_frame, FrameError, MAX_FRAME_BYTES};
pub use messages::{
    ErrorReply, InferReply, InferRequest, LayerBlob, PatternInfo, Request, Response, SegmentBlob,
};
