//! The shared store handle: staged write-ahead mutations over the
//! segment log.

use super::log::SegmentLog;
use super::{Column, LayerExt, ReadLayer, WriteLayer};
use crate::metrics::Metrics;
use crate::sched::batch::lock_recover;
use qpart_core::json::Value;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// One staged mutation: `value: Some` = put, `None` = delete.
struct StagedOp {
    col: Column,
    key: Vec<u8>,
    value: Option<Vec<u8>>,
}

/// The process-wide durable store handle, shared by every cache facade
/// and the housekeeping thread.
///
/// Serving paths never touch the disk: a cache insert/evict calls
/// [`StoreTier::stage_put`]/[`StoreTier::stage_delete`], which pushes one
/// op onto an in-memory queue under a short lock. The housekeeping thread
/// periodically calls [`StoreTier::flush`], which drains the queue
/// through a [`Temporal`](super::Temporal) overlay (collapsing repeated
/// writes to one record per key) and commits it to the [`SegmentLog`] in
/// one deterministic sweep, then syncs. [`StoreTier::maybe_compact`]
/// rides the same cadence.
pub struct StoreTier {
    log: Mutex<SegmentLog>,
    staged: Mutex<Vec<StagedOp>>,
    flushes: AtomicU64,
    staged_total: AtomicU64,
}

impl StoreTier {
    /// Open (and replay) the segment log under `dir`.
    pub fn open(dir: &Path) -> std::io::Result<Arc<StoreTier>> {
        Ok(Arc::new(StoreTier {
            log: Mutex::new(SegmentLog::open(dir)?),
            staged: Mutex::new(Vec::new()),
            flushes: AtomicU64::new(0),
            staged_total: AtomicU64::new(0),
        }))
    }

    /// Stage an insert/replace for the next flush (cheap, lock-bounded).
    pub fn stage_put(&self, col: Column, key: Vec<u8>, value: Vec<u8>) {
        lock_recover(&self.staged).push(StagedOp { col, key, value: Some(value) });
        Metrics::inc(&self.staged_total);
    }

    /// Stage a delete (an evicted cache entry) for the next flush.
    pub fn stage_delete(&self, col: Column, key: Vec<u8>) {
        lock_recover(&self.staged).push(StagedOp { col, key, value: None });
        Metrics::inc(&self.staged_total);
    }

    /// Ops staged since the last flush.
    pub fn staged_len(&self) -> usize {
        lock_recover(&self.staged).len()
    }

    /// Drain the staged ops into the log (via a write-ahead overlay, so a
    /// key staged N times costs one record) and sync. Returns the number
    /// of ops drained.
    pub fn flush(&self) -> usize {
        let ops: Vec<StagedOp> = std::mem::take(&mut *lock_recover(&self.staged));
        let mut log = lock_recover(&self.log);
        if !ops.is_empty() {
            let mut overlay = log.temporal();
            for op in &ops {
                match &op.value {
                    Some(v) => overlay.put(op.col, &op.key, v),
                    None => overlay.delete(op.col, &op.key),
                }
            }
            overlay.commit();
        }
        log.flush();
        Metrics::inc(&self.flushes);
        ops.len()
    }

    /// Compact the log if it is mostly dead weight. Returns whether a
    /// compaction ran.
    pub fn maybe_compact(&self) -> bool {
        lock_recover(&self.log).maybe_compact()
    }

    /// The live `(key, value)` set of `col`, sorted by key — what warm
    /// replay iterates. (Does not include unflushed staged ops.)
    pub fn snapshot(&self, col: Column) -> Vec<(Vec<u8>, Vec<u8>)> {
        lock_recover(&self.log).entries(col)
    }

    /// A live value (staged unflushed ops included — tests and the
    /// replication hook read through this).
    pub fn get(&self, col: Column, key: &[u8]) -> Option<Vec<u8>> {
        let staged = lock_recover(&self.staged);
        for op in staged.iter().rev() {
            if op.col == col && op.key == key {
                return op.value.clone();
            }
        }
        drop(staged);
        lock_recover(&self.log).get(col, key)
    }

    /// Replayed-but-unreadable records seen at open
    /// (`store_corrupt_records_total`).
    pub fn corrupt_records(&self) -> u64 {
        lock_recover(&self.log).corrupt_records()
    }

    /// The `store` section of the stats document.
    pub fn to_json(&self) -> Value {
        let (records, total_bytes, live, corrupt, dropped_tail, io_errors, compactions) = {
            let log = lock_recover(&self.log);
            (
                log.records(),
                log.total_bytes(),
                log.live_len(),
                log.corrupt_records(),
                log.dropped_tail_bytes(),
                log.io_errors(),
                log.compactions(),
            )
        };
        Value::obj([
            ("records", records.into()),
            ("log_bytes", total_bytes.into()),
            ("live_entries", live.into()),
            ("corrupt_records", corrupt.into()),
            ("dropped_tail_bytes", dropped_tail.into()),
            ("io_errors", io_errors.into()),
            ("compactions", compactions.into()),
            ("flushes", self.flushes.load(Ordering::Relaxed).into()),
            ("staged_ops_total", self.staged_total.load(Ordering::Relaxed).into()),
            ("staged_pending", (self.staged_len() as u64).into()),
        ])
    }

    /// `(records, log_bytes, live_entries, corrupt_records, io_errors,
    /// compactions, flushes)` for the Prometheus surface.
    pub fn counters(&self) -> (u64, u64, u64, u64, u64, u64, u64) {
        let log = lock_recover(&self.log);
        (
            log.records(),
            log.total_bytes(),
            log.live_len(),
            log.corrupt_records(),
            log.io_errors(),
            log.compactions(),
            self.flushes.load(Ordering::Relaxed),
        )
    }
}

impl std::fmt::Debug for StoreTier {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let log = lock_recover(&self.log);
        f.debug_struct("StoreTier")
            .field("records", &log.records())
            .field("log_bytes", &log.total_bytes())
            .field("staged", &self.staged_len())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn store_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("qpart-tier-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn staged_ops_become_durable_on_flush() {
        let dir = store_dir("flush");
        {
            let tier = StoreTier::open(&dir).unwrap();
            tier.stage_put(Column::Decision, b"k".to_vec(), b"v1".to_vec());
            tier.stage_put(Column::Decision, b"k".to_vec(), b"v2".to_vec());
            tier.stage_put(Column::Reply, b"r".to_vec(), b"body".to_vec());
            tier.stage_delete(Column::Reply, b"r".to_vec());
            // staged-but-unflushed state reads through
            assert_eq!(tier.get(Column::Decision, b"k"), Some(b"v2".to_vec()));
            assert_eq!(tier.get(Column::Reply, b"r"), None);
            assert_eq!(tier.flush(), 4);
            assert_eq!(tier.staged_len(), 0);
        }
        let tier = StoreTier::open(&dir).unwrap();
        assert_eq!(tier.get(Column::Decision, b"k"), Some(b"v2".to_vec()));
        assert_eq!(tier.get(Column::Reply, b"r"), None);
        // the overlay collapsed k's two puts into one record; r's
        // put+delete netted to nothing
        let snap = tier.snapshot(Column::Decision);
        assert_eq!(snap, vec![(b"k".to_vec(), b"v2".to_vec())]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stats_document_has_the_store_shape() {
        let dir = store_dir("stats");
        let tier = StoreTier::open(&dir).unwrap();
        tier.stage_put(Column::Plan, b"p".to_vec(), Vec::new());
        tier.flush();
        let v = tier.to_json();
        for k in [
            "records",
            "log_bytes",
            "live_entries",
            "corrupt_records",
            "dropped_tail_bytes",
            "io_errors",
            "compactions",
            "flushes",
            "staged_ops_total",
            "staged_pending",
        ] {
            assert!(v.get(k).is_some(), "{k}");
        }
        assert_eq!(v.get("records").and_then(Value::as_i64), Some(1));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
