//! **Fig. 10** — Layer-wise Communication Payload Comparison (4 schemes).
//!
//! Paper: QPART's payload is far below all baselines at every partition
//! point (>80 % reduction); the autoencoder compresses only the uplink
//! activation, so its payload stays close to No-Optimization; pruning
//! scales payload by the kept fraction.

mod common;

use common::*;
use qpart::prelude::*;
use qpart_bench::{fmt_bits, Table};

fn main() {
    let setup = mlp6_setup();
    banner("Fig. 10 — layer-wise communication payload, 4 schemes (mlp6)", setup.calibrated);
    let cost = CostModel::paper_default();
    let arch = &setup.arch;
    let list = schemes();

    let mut table = Table::new(
        "payload vs partition point",
        &["p", "QPART", "No Optimization", "Model Pruning", "Auto-Encoder", "QPART reduction"],
    );
    let mut reductions = Vec::new();
    for p in 0..=arch.num_layers() {
        let vals: Vec<u64> = list
            .iter()
            .map(|&s| {
                scheme_cost(s, arch, &cost, p, Some(&setup.patterns), LEVEL_1PCT)
                    .unwrap()
                    .payload_bits
            })
            .collect();
        let reduction = 1.0 - vals[0] as f64 / vals[1] as f64;
        reductions.push(reduction);
        table.row(
            std::iter::once(p.to_string())
                .chain(vals.iter().map(|&v| fmt_bits(v)))
                .chain(std::iter::once(format!("{:.1}%", reduction * 100.0)))
                .collect(),
        );
    }
    table.print();
    let avg = reductions[1..].iter().sum::<f64>() / (reductions.len() - 1) as f64;
    println!(
        "\npaper: >80 % payload reduction vs no-optimization — measured average over \
         p ≥ 1: {:.1} %.",
        avg * 100.0
    );
}
