//! TCP front-end: JSON-lines (+ negotiated binary frames) over TCP, a
//! bounded job queue, and a configurable **executor pool** of inference
//! workers fed by the batch-aware serving dataplane ([`crate::sched`]).
//!
//! Topology: the front-end (by default the poll-based **reactor**,
//! [`crate::net`]) parses frames and submits [`Job`]s into a **bounded**
//! channel — the admission-control point: when the queue is full the
//! request is shed immediately with an `overloaded` error instead of
//! growing latency unboundedly. `workers` inference threads each own a
//! full [`Service`] (Algorithm 1 tables + PJRT executor — PJRT clients
//! are single-device and not `Send`, so per-worker ownership is the
//! honest parallelism model) and **drain the queue in batches**
//! ([`crate::sched::drain_batch`]): same-(model, accuracy level,
//! partition) `infer` requests in a batch are planned and encoded once,
//! and the shared [`qpart_proto::EncodedSegmentBody`] fans out to every
//! waiting connection. One `Arc<Bundle>` backs the whole pool (a single
//! resident copy of the weights), one [`EncodedReplyCache`] keeps
//! encoded replies across batches, and a GC thread expires sessions
//! whose device never uploaded. Sessions live in one sharded
//! [`SharedSessionTable`] so the two protocol phases may be handled by
//! different workers; per-worker metrics are aggregated by a
//! [`MetricsHub`] into one logical [`MetricsSnapshot`].
//!
//! Front-ends ([`Frontend`]): the reactor holds every accepted device as
//! a state machine on one thread — connection count is gated by
//! `max_conns`, not by OS threads — while [`Frontend::Threaded`] keeps
//! the classic thread-per-connection loop as the comparison baseline
//! (and the non-unix fallback). Both speak the identical wire protocol;
//! `bench-serve` checks reply byte-identity between them. Either way,
//! `workers` mirrors the simulator's `FleetConfig::server_slots` knob
//! (qpart-sim), so modeled and live serving share one parallelism model.

use crate::brownout::BrownoutController;
use crate::decision::DecisionCache;
use crate::metrics::{request_path, ClassRegistry, Metrics, MetricsHub, MetricsSnapshot};
use crate::obs::{JobTrace, Stage, TraceSink, Tracer, TrafficRecorder, FRONT_WORKER};
use crate::sched::{
    drain_batch, BatchPolicy, DrainOutcome, EncodedReplyCache, FairQueue, Job, ReplySink,
    StampedReply, WireReply,
};
use crate::service::{FaultSpec, Service, ServiceOptions};
use crate::session::SharedSessionTable;
use crate::store::StoreTier;
use qpart_proto::frame::{read_any_frame, write_binary_frame, write_frame, Frame, FrameError};
use qpart_proto::messages::{ErrorReply, HelloReply, Request, Response};
use qpart_runtime::{Bundle, CompileCache};
use std::io::{BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Server configuration.
///
/// Knobs and what they control:
///
/// * `listen` — TCP listen address; port `0` binds an ephemeral port
///   (the bound address is reported in [`ServerHandle::addr`]).
/// * `workers` — size of the executor pool: how many inference threads
///   (each owning its own PJRT executor + Algorithm 1 tables) drain the
///   job queue concurrently. `1` reproduces the classic single-inference-
///   thread coordinator; the default (`4`) mirrors the simulator's
///   `FleetConfig::server_slots` default so modeled and live serving agree.
///   Caution for `real-xla` builds: the pool shares compiled executables
///   through the compile cache; if the swapped-in bindings' handles are
///   not thread-safe for concurrent execution, run `workers = 1` (see the
///   README's "Real XLA" notes — the offline stub and PJRT CPU are safe).
/// * `queue_capacity` — **admission control**: the bounded depth of the
///   shared job queue. When all workers are busy and the queue is full,
///   new requests are shed immediately with an `overloaded` error rather
///   than queuing unboundedly (tail latency stays bounded under overload;
///   sheds are counted in `shed_total`).
/// * `session_capacity` — total capacity of the sharded session table for
///   the two-phase protocol. Oldest sessions are evicted first when a
///   shard fills (devices that never upload their activation must not
///   leak memory).
/// * `session_ttl` — age bound on open sessions: a GC thread sweeps
///   sessions older than this (counted in `sessions_expired`). Zero
///   disables the sweep (capacity eviction still applies).
/// * `batch_window` — the coalescing window: after a worker dequeues its
///   first job it waits up to this long for more, so concurrent
///   same-pattern requests share one encode. Zero (the default) still
///   coalesces whatever is already queued, adding no latency.
/// * `batch_max` — cap on jobs per drained batch.
/// * `cache_bytes` — byte budget of the encoded-reply cache (LRU beyond
///   it). The most recent entry always stays resident.
/// * `binary_frames` — allow connections to negotiate length-prefixed
///   binary frames via `hello` (JSON-lines stays the default and the
///   fallback for peers that never negotiate). The grant is symmetric:
///   segment replies go out as binary frames and activation uploads may
///   come in as binary request frames.
/// * `frontend` — how connections are carried: [`Frontend::Reactor`]
///   (default) multiplexes every accepted socket over one poll-based
///   event loop, so accepted-device count is bounded by `max_conns`
///   rather than by OS threads; [`Frontend::Threaded`] is the classic
///   thread-per-connection loop (baseline / non-unix fallback). The wire
///   protocol is identical either way.
/// * `max_conns` — accept gate: protocol connections beyond this are
///   refused with a `max_conns` error line and counted in
///   `conns_rejected_total` (they never consume server state).
/// * `conn_idle` — idle/slow-client timeout: a connection with no
///   request in flight and no byte moved for this long is closed
///   (`conns_timed_out`). Defuses slow-loris and half-open peers. Zero
///   disables. The default matches `session_ttl` (600 s): a device may
///   legitimately go quiet for its whole device-side compute window
///   between phase 1 and phase 2, so the connection bound must not be
///   tighter than the session bound.
/// * `fair_rate` — per-connection fair queuing ([`FairQueue`]): the base
///   sustained requests/s each connection may enqueue (with a 2-second
///   burst allowance) before being refused with a `throttled` error
///   (`sched_throttled_total`). A connection's `hello` may declare a
///   device-class weight that scales its rate and burst (clamped
///   server-side). Keeps one hot device from starving the rest of the
///   fleet. Zero (the default) disables the limiter.
/// * `metrics_listen` — optional second listen address serving a
///   plaintext Prometheus-style scrape of the stats document (the
///   pull-only wire `stats` request stays; this is for standard
///   scrapers). Rides the reactor as a second listener socket; under
///   [`Frontend::Threaded`] a dedicated acceptor thread answers each
///   scrape inline. Both render through one shared helper
///   (`MetricsHub::scrape_http_response`), so the output cannot
///   diverge between front-ends.
/// * `trace_sample` — accept-sampling rate in `[0, 1]` for the tracing
///   layer ([`crate::obs`]): every sampled connection's requests get a
///   per-stage span timeline collected into the trace store (served on
///   the metrics listener as `/trace` / `/trace?id=` / `/trace/slow`).
///   Sampled traces are server-side only — no wire byte changes — and
///   `0` (the default) makes the whole layer a single `Option` check
///   per request. Peers may additionally negotiate `trace: true` in
///   `hello` to get their trace id echoed in replies; that works
///   regardless of the sampling rate.
/// * `trace_slow_us` — slow-request exemplar threshold: traced requests
///   whose timeline spans at least this long are kept as one of the
///   `trace_slow_keep` worst full timelines (`/trace/slow`), surviving
///   FIFO eviction from the main store. Zero disables exemplars.
/// * `trace_slow_keep` — how many worst timelines `/trace/slow` keeps.
/// * `trace_store` — bounded trace-store capacity (complete timelines,
///   FIFO-evicted; evictions are counted in `dropped_spans`).
/// * `record_trace` — optional path: capture admitted live traffic
///   (arrival times, device profile scalars, phase-2 uploads) into the
///   scenario engine's `trace v1` text format, replayable with
///   `bench-scenario` ([`TrafficRecorder`]). Flushed periodically and
///   at shutdown.
/// * `warm` — cache pre-warming at startup ([`WarmMode`]):
///   [`WarmMode::Paper`] has one worker encode the most-likely
///   `(model, level, partition)` reply keys (Algorithm 1 enumerates
///   them; Algorithm 2 under the paper-default profile picks per level)
///   and pre-build their phase-2 plans; [`WarmMode::Log`] replays the
///   durable segment log under `store_dir` instead, restoring the
///   previous process's **recorded** decision/reply working set
///   byte-identically (`warmed_total` in stats either way).
/// * `store_dir` — durable warm-state directory: cache inserts are
///   staged and flushed to an append-only CRC-guarded segment log by the
///   housekeeping thread (which also compacts it), so a restart with
///   `warm = WarmMode::Log` comes up hot ([`crate::store`]).
/// * `host_fallback` — run phase 2 on the pure-Rust reference kernels
///   (linear architectures only). For tests and `bench-serve`; a PJRT
///   deployment leaves this off.
/// * `artifacts_dir` — artifact bundle directory (`make artifacts`);
///   loaded **once** and shared across the pool via `Arc`.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Listen address, e.g. `127.0.0.1:7878` (port 0 = ephemeral).
    pub listen: String,
    /// Executor-pool size (inference worker threads, each owning a PJRT
    /// executor). Values < 1 are treated as 1.
    pub workers: usize,
    /// Bounded job-queue depth (admission control).
    pub queue_capacity: usize,
    /// Session-table capacity (total across shards).
    pub session_capacity: usize,
    /// Session age bound for the GC sweep (zero = no TTL sweep).
    pub session_ttl: Duration,
    /// Coalescing window per drained batch (zero = opportunistic only).
    pub batch_window: Duration,
    /// Max jobs per drained batch (values < 1 behave as 1).
    pub batch_max: usize,
    /// Encoded-reply cache byte budget.
    pub cache_bytes: usize,
    /// Allow binary-frame negotiation (symmetric: segment replies
    /// downlink AND activation uploads uplink).
    pub binary_frames: bool,
    /// Connection-handling model (reactor by default).
    pub frontend: Frontend,
    /// Accept gate: refuse protocol connections beyond this many.
    pub max_conns: usize,
    /// Idle/slow-client timeout (zero = never time out).
    pub conn_idle: Duration,
    /// Per-connection fair-queue admission rate (requests/s; 0 = off).
    pub fair_rate: f64,
    /// Optional plaintext metrics-scrape listen address.
    pub metrics_listen: Option<String>,
    /// Trace accept-sampling rate in `[0, 1]` (0 = sampling off).
    pub trace_sample: f64,
    /// Slow-exemplar threshold in µs (0 = no slow capture).
    pub trace_slow_us: u64,
    /// How many worst timelines `/trace/slow` retains.
    pub trace_slow_keep: usize,
    /// Trace-store capacity in complete timelines (FIFO eviction).
    pub trace_store: usize,
    /// Optional `trace v1` live-traffic capture path.
    pub record_trace: Option<String>,
    /// Cache pre-warming at startup: paper-default profile encoding, or
    /// replay of the durable segment log (requires `store_dir`). Runs on
    /// one worker before the server accepts traffic.
    pub warm: WarmMode,
    /// Durable warm-state directory (`--store-dir`): stage cache inserts
    /// into an append-only segment log so the next restart can warm from
    /// it. `None` (the default) keeps serving fully in-memory.
    pub store_dir: Option<String>,
    /// Execute phase 2 with the pure-Rust host reference kernels instead
    /// of PJRT (tests / bench-serve; linear architectures only).
    pub host_fallback: bool,
    /// Brownout entry threshold on the queue-wait EWMA, in µs: sustained
    /// queue waits above this (or connection-count pressure near
    /// `max_conns`) step the degradation ladder up, and calm steps it
    /// back down ([`crate::brownout`]). Degraded requests are planned at
    /// a coarser accuracy level **only when the Algorithm 1 degradation
    /// table says their budget still holds**. Zero (the default)
    /// disables the controller entirely — the plan path is untouched.
    pub brownout_wait_us: u64,
    /// Soft per-batch watchdog: a worker that has been executing one
    /// batch for longer than this is counted in `job_timeouts_total`
    /// (once per offending batch — the job is not killed; the counter
    /// is the alarm). Zero (the default) disables the watchdog.
    pub job_timeout: Duration,
    /// Compiled-in fault injection for the chaos harness
    /// ([`FaultSpec`]): worker panics, execution delay, allocation
    /// failures. `None` (the default) is the production path; the CLI
    /// additionally refuses to arm it unless `QPART_FAULT_INJECT=1`.
    pub fault_inject: Option<FaultSpec>,
    /// Artifact bundle directory.
    pub artifacts_dir: String,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            listen: "127.0.0.1:0".into(),
            // mirrors FleetConfig::default().server_slots (qpart-sim)
            workers: 4,
            // mirrors the config system's serving.queue_capacity default
            queue_capacity: 1024,
            session_capacity: 4096,
            session_ttl: Duration::from_secs(600),
            batch_window: Duration::ZERO,
            batch_max: 32,
            cache_bytes: 64 << 20,
            binary_frames: true,
            frontend: Frontend::Reactor,
            max_conns: 4096,
            // matches session_ttl: a session-holding device may be
            // silently computing for up to the session's lifetime
            conn_idle: Duration::from_secs(600),
            fair_rate: 0.0,
            metrics_listen: None,
            trace_sample: 0.0,
            trace_slow_us: 0,
            trace_slow_keep: 8,
            trace_store: 1024,
            record_trace: None,
            warm: WarmMode::Off,
            store_dir: None,
            host_fallback: false,
            brownout_wait_us: 0,
            job_timeout: Duration::ZERO,
            fault_inject: None,
            artifacts_dir: "artifacts".into(),
        }
    }
}

/// How the front-end carries accepted connections.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Frontend {
    /// Poll-based connection reactor ([`crate::net`]): one event-loop
    /// thread owns every accepted socket as an explicit state machine.
    /// Accepted-device count scales to `max_conns`, not to OS threads.
    /// Falls back to [`Frontend::Threaded`] on non-unix targets.
    Reactor,
    /// Thread-per-connection (the pre-reactor topology): simple,
    /// blocking, and capped by OS threads — kept as the behavioral
    /// baseline the reactor is byte-identical to.
    Threaded,
}

/// What populates the shared caches before the server accepts traffic
/// (`--warm off|paper|log`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WarmMode {
    /// No pre-warming: caches fill from live traffic.
    #[default]
    Off,
    /// Encode the paper-default profile's most-likely reply keys and
    /// pre-build their phase-2 plans ([`Service::warm_cache`]) — the
    /// behavior of the old `--warm-cache` flag.
    Paper,
    /// Replay the durable segment log under [`ServerConfig::store_dir`]
    /// ([`Service::warm_from_store`]): the previous process's recorded
    /// decision/reply working set comes back byte-identical.
    Log,
}

impl WarmMode {
    /// Parse the CLI/config form.
    pub fn parse(s: &str) -> Result<WarmMode, String> {
        match s.trim() {
            "off" => Ok(WarmMode::Off),
            "paper" => Ok(WarmMode::Paper),
            "log" => Ok(WarmMode::Log),
            other => Err(format!("warm mode `{other}` is not off|paper|log")),
        }
    }

    /// The canonical config-file spelling.
    pub fn as_str(&self) -> &'static str {
        match self {
            WarmMode::Off => "off",
            WarmMode::Paper => "paper",
            WarmMode::Log => "log",
        }
    }
}

/// Handle to a running server (for tests/examples).
pub struct ServerHandle {
    pub addr: std::net::SocketAddr,
    /// Bound address of the metrics-scrape listener, when configured.
    pub metrics_addr: Option<std::net::SocketAddr>,
    /// Aggregated + per-worker metrics.
    pub hub: Arc<MetricsHub>,
    /// The shared session table (observability in tests/examples).
    pub sessions: Arc<SharedSessionTable>,
    /// The shared encoded-reply cache (observability in tests/examples).
    pub cache: Arc<EncodedReplyCache>,
    /// The pool-wide compile cache (observability in tests/examples).
    pub compile_cache: Arc<CompileCache>,
    /// The server-wide Algorithm-2 decision cache (observability in
    /// tests/examples).
    pub decision_cache: Arc<DecisionCache>,
    /// The trace sink: stored timelines, slow exemplars, Chrome trace
    /// export (`bench-serve --trace-out` reads it through this handle).
    pub trace: Arc<TraceSink>,
    /// Live-traffic recorder, when `record_trace` is configured.
    pub recorder: Option<Arc<TrafficRecorder>>,
    /// The durable store tier, when `store_dir` is configured
    /// (observability in tests / bench-serve restart measurement).
    pub store: Option<Arc<StoreTier>>,
    stop: Arc<AtomicBool>,
    drain: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    /// Threaded-frontend scrape acceptor (None under the reactor, which
    /// carries the scrape listener on its own thread).
    metrics_thread: Option<JoinHandle<()>>,
    gc_thread: Option<JoinHandle<()>>,
    /// Executor workers, shared with the housekeeping thread's
    /// supervisor (which joins dead workers and respawns replacements).
    workers: Arc<Mutex<Vec<WorkerSlot>>>,
}

impl ServerHandle {
    /// Signal shutdown and join the threads.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // poke the acceptors so they re-check the stop flag
        let _ = TcpStream::connect(self.addr);
        if let Some(m) = self.metrics_addr {
            let _ = TcpStream::connect(m);
        }
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        if let Some(t) = self.metrics_thread.take() {
            let _ = t.join();
        }
        // the supervisor rides the gc thread; join it before draining the
        // worker slots so nothing respawns behind our back (it also
        // refuses to respawn once the stop flag is up)
        if let Some(t) = self.gc_thread.take() {
            let _ = t.join();
        }
        let slots: Vec<WorkerSlot> = {
            let mut w = self.workers.lock().unwrap_or_else(|e| e.into_inner());
            w.drain(..).collect()
        };
        for slot in slots {
            let _ = slot.handle.join();
        }
        // workers are parked: collect their final spans, persist any
        // recorded traffic, and make every staged store op durable so a
        // `--warm log` restart sees the complete working set
        self.trace.drain();
        if let Some(rec) = &self.recorder {
            let _ = rec.flush();
        }
        if let Some(tier) = &self.store {
            tier.flush();
        }
    }

    /// Flip the server into drain mode without stopping it: new protocol
    /// connections are refused with a `draining` error while existing
    /// connections finish their in-flight work, flush their replies, and
    /// close. Idempotent.
    pub fn begin_drain(&self) {
        self.drain.store(true, Ordering::SeqCst);
    }

    /// Whether drain mode is active.
    pub fn draining(&self) -> bool {
        self.drain.load(Ordering::SeqCst)
    }

    /// Graceful shutdown: enter drain mode, wait up to `timeout` for
    /// every protocol connection to finish in flight work and close
    /// (`conns_open` reaching zero), then stop and join the threads.
    /// Returns `true` when the fleet drained fully within the bound,
    /// `false` when the timeout forced the exit.
    pub fn drain(self, timeout: Duration) -> bool {
        self.begin_drain();
        let front = self.hub.front();
        let deadline = Instant::now() + timeout;
        let mut clean = front.conns_open.load(Ordering::Relaxed) == 0;
        while !clean && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(20));
            clean = front.conns_open.load(Ordering::Relaxed) == 0;
        }
        self.shutdown();
        clean
    }

    /// One aggregated snapshot across the front-end and all workers.
    pub fn snapshot(&self) -> MetricsSnapshot {
        self.hub.snapshot()
    }

    /// Per-worker snapshots (diagnostics / load-balance checks).
    pub fn worker_snapshots(&self) -> Vec<MetricsSnapshot> {
        self.hub.worker_snapshots()
    }
}

/// Start the server; returns once the listener is bound, the bundle is
/// loaded (once, shared), and **every** worker's service (Algorithm 1
/// tables + PJRT) is initialized.
pub fn serve(cfg: ServerConfig) -> Result<ServerHandle, String> {
    let listener = TcpListener::bind(&cfg.listen).map_err(|e| format!("bind {}: {e}", cfg.listen))?;
    let addr = listener.local_addr().map_err(|e| e.to_string())?;
    let workers = cfg.workers.max(1);
    let hub = Arc::new(MetricsHub::new());
    let sessions = Arc::new(SharedSessionTable::new(cfg.session_capacity, workers));
    let cache = Arc::new(EncodedReplyCache::new(cfg.cache_bytes));
    // one compile cache for the whole pool: executables / prepared
    // segments / phase-2 plans build once per server, not once per worker
    let compile_cache = Arc::new(CompileCache::new());
    // one Algorithm-2 decision cache for the whole pool: repeat
    // (model, level, profile) requests skip planning on every worker
    let decision_cache = Arc::new(DecisionCache::new());
    // durable warm state: open (and replay) the segment log, then attach
    // it to the cache facades so inserts/evictions stage log records
    let store = match &cfg.store_dir {
        Some(dir) => {
            let tier = StoreTier::open(std::path::Path::new(dir))
                .map_err(|e| format!("store {dir}: {e}"))?;
            cache.attach_store(Arc::clone(&tier));
            decision_cache.attach_store(Arc::clone(&tier));
            hub.register_store(Arc::clone(&tier));
            Some(tier)
        }
        None => None,
    };
    if cfg.warm == WarmMode::Log && store.is_none() {
        return Err("warm mode `log` requires a store_dir".into());
    }
    // per-connection fair-queue token buckets (inert when fair_rate == 0)
    let fair = Arc::new(FairQueue::new(cfg.fair_rate));
    // the trace sink always exists (hello-negotiated grants must work
    // even with sampling off); disabled tracing costs one Option check
    // per request and emits no spans
    let trace = TraceSink::new(
        cfg.trace_sample,
        cfg.trace_slow_us,
        cfg.trace_slow_keep,
        cfg.trace_store,
    );
    hub.register_trace_sink(Arc::clone(&trace));
    let recorder = cfg.record_trace.as_deref().map(TrafficRecorder::new);
    let stop = Arc::new(AtomicBool::new(false));

    // one resident bundle for the whole pool (weights are immutable)
    let bundle =
        Arc::new(Bundle::load(&cfg.artifacts_dir).map_err(|e| format!("bundle: {e}"))?);

    let (job_tx, job_rx): (SyncSender<Job>, Receiver<Job>) = sync_channel(cfg.queue_capacity);
    // Work-stealing hand-off: workers take turns locking the receiver to
    // drain the next *batch* (everything queued, plus up to
    // `batch_window` of stragglers in short interleavable lock slices —
    // see `drain_batch`). Handling happens outside the lock, so up to
    // `workers` batches are in flight concurrently.
    let job_rx = Arc::new(Mutex::new(job_rx));
    let policy = BatchPolicy { window: cfg.batch_window, max_batch: cfg.batch_max };

    // Brownout controller: one for the whole server (the EWMA must see
    // every worker's queue waits); `None` when disabled, and then the
    // plan path is byte-identical to a build without the feature.
    let brownout = BrownoutController::new(cfg.brownout_wait_us, hub.front());
    // Graceful-drain flag, shared by the front-end and the handle.
    let drain = Arc::new(AtomicBool::new(false));

    // Inference workers: each owns a (non-Send) service over the shared
    // bundle. Algorithm 1 initialization happens inside; readiness is
    // reported via a channel so `serve` fails fast if any worker cannot
    // start. The spawn context is retained by the supervisor (on the
    // housekeeping thread) so a worker that dies mid-batch — a panic is
    // caught, answered, and lets the thread exit — is replaced by a
    // fresh service (`worker_restarts_total`).
    let ctx = WorkerCtx {
        hub: Arc::clone(&hub),
        sessions: Arc::clone(&sessions),
        cache: Arc::clone(&cache),
        compile_cache: Arc::clone(&compile_cache),
        decision_cache: Arc::clone(&decision_cache),
        bundle: Arc::clone(&bundle),
        stop: Arc::clone(&stop),
        job_rx: Arc::clone(&job_rx),
        policy,
        host_fallback: cfg.host_fallback,
        trace: Arc::clone(&trace),
        brownout: brownout.clone(),
        faults: cfg.fault_inject,
        store: store.clone(),
        epoch: Instant::now(),
    };
    let (ready_tx, ready_rx) = sync_channel::<Result<(), String>>(workers);
    let mut slots = Vec::with_capacity(workers);
    for w in 0..workers {
        // one worker warms the shared caches; its peers see the results
        let warm = if w == 0 { cfg.warm } else { WarmMode::Off };
        slots.push(spawn_worker(&ctx, w, warm, Some(ready_tx.clone()))?);
    }
    drop(ready_tx);

    for _ in 0..workers {
        match ready_rx.recv() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => return Err(format!("service init failed: {e}")),
            Err(_) => return Err("a worker thread died during init".into()),
        }
    }
    let worker_slots = Arc::new(Mutex::new(slots));

    // Housekeeping: expire sessions whose device never uploaded, drain
    // worker span rings into the trace store (keeps ring pressure down
    // between endpoint hits), persist recorded traffic so a killed
    // `serve` still leaves a usable capture — and, every tick, supervise
    // the executor pool (respawn dead workers, run the soft job
    // watchdog) and advance the brownout controller's pressure clock.
    let gc_thread = {
        let gc_sessions = Arc::clone(&sessions);
        let gc_stop = Arc::clone(&stop);
        let gc_trace = Arc::clone(&trace);
        let gc_recorder = recorder.clone();
        let gc_workers = Arc::clone(&worker_slots);
        let gc_brownout = brownout.clone();
        let gc_store = store.clone();
        let gc_front = hub.front();
        let job_timeout = cfg.job_timeout;
        let max_conns = cfg.max_conns.max(1);
        let ttl = cfg.session_ttl;
        let interval = if ttl > Duration::ZERO {
            (ttl / 4).clamp(Duration::from_millis(10), Duration::from_secs(1))
        } else {
            Duration::from_secs(1)
        };
        Some(
            std::thread::Builder::new()
                .name("qpart-session-gc".into())
                .spawn(move || {
                    // sleep in short ticks so shutdown joins promptly even
                    // with a long sweep interval
                    let tick = Duration::from_millis(10).min(interval);
                    let mut slept = Duration::ZERO;
                    while !gc_stop.load(Ordering::SeqCst) {
                        std::thread::sleep(tick);
                        slept += tick;
                        // pressure clock: the controller's hysteresis
                        // counts these ticks, so the gc cadence (~10 ms)
                        // is part of its time constants
                        if let Some(b) = &gc_brownout {
                            let open = gc_front.conns_open.load(Ordering::Relaxed) as usize;
                            b.tick(open, max_conns);
                        }
                        supervise_workers(&gc_workers, &ctx, &gc_front, job_timeout);
                        if slept >= interval {
                            slept = Duration::ZERO;
                            if ttl > Duration::ZERO {
                                gc_sessions.sweep_expired(ttl);
                            }
                            gc_trace.drain();
                            if let Some(rec) = &gc_recorder {
                                let _ = rec.flush();
                            }
                            // make staged cache mutations durable, and
                            // rewrite the log when it is mostly dead
                            if let Some(tier) = &gc_store {
                                tier.flush();
                                tier.maybe_compact();
                            }
                        }
                    }
                })
                .map_err(|e| e.to_string())?,
        )
    };

    // Optional plaintext metrics-scrape listener (second socket).
    let metrics_listener = match &cfg.metrics_listen {
        Some(addr) => Some(
            TcpListener::bind(addr).map_err(|e| format!("bind metrics {addr}: {e}"))?,
        ),
        None => None,
    };
    let metrics_addr = match &metrics_listener {
        Some(l) => Some(l.local_addr().map_err(|e| e.to_string())?),
        None => None,
    };

    // Front-end thread: the poll-based reactor by default, or the
    // thread-per-connection baseline. Identical wire behavior.
    let (accept_thread, metrics_thread) = spawn_frontend(
        &cfg,
        listener,
        metrics_listener,
        job_tx,
        Arc::clone(&hub),
        Arc::clone(&sessions),
        fair,
        Arc::clone(&trace),
        recorder.clone(),
        Arc::clone(&stop),
        Arc::clone(&drain),
    )?;

    Ok(ServerHandle {
        addr,
        metrics_addr,
        hub,
        sessions,
        cache,
        compile_cache,
        decision_cache,
        trace,
        recorder,
        store,
        stop,
        drain,
        accept_thread: Some(accept_thread),
        metrics_thread,
        gc_thread,
        workers: worker_slots,
    })
}

/// Everything needed to (re)spawn one executor worker. Retained by the
/// housekeeping thread's supervisor so a dead worker can be replaced by
/// a fresh service over the same shared state.
struct WorkerCtx {
    hub: Arc<MetricsHub>,
    sessions: Arc<SharedSessionTable>,
    cache: Arc<EncodedReplyCache>,
    compile_cache: Arc<CompileCache>,
    decision_cache: Arc<DecisionCache>,
    bundle: Arc<Bundle>,
    stop: Arc<AtomicBool>,
    job_rx: Arc<Mutex<Receiver<Job>>>,
    policy: BatchPolicy,
    host_fallback: bool,
    trace: Arc<TraceSink>,
    brownout: Option<Arc<BrownoutController>>,
    faults: Option<FaultSpec>,
    /// The durable store tier (`--store-dir`), for log-replay warming.
    store: Option<Arc<StoreTier>>,
    /// Time zero for the `busy_since_us` watchdog timestamps.
    epoch: Instant,
}

/// Supervisor bookkeeping for one executor worker.
struct WorkerSlot {
    /// Worker index — stable across respawns (names the thread and the
    /// tracer lane).
    idx: usize,
    handle: JoinHandle<()>,
    /// Microseconds since [`WorkerCtx::epoch`] when the worker began its
    /// current batch; 0 = idle. Written by the worker, read by the soft
    /// job watchdog.
    busy_since_us: Arc<AtomicU64>,
    /// The busy timestamp the watchdog last counted, so one stuck batch
    /// increments `job_timeouts_total` once, not once per sweep.
    flagged_busy_us: u64,
}

/// Spawn worker `idx`. `ready_tx` reports first-spawn init results so
/// `serve` can fail fast; supervisor respawns pass `None` — a
/// replacement whose service fails to initialize backs off briefly and
/// exits, and the supervisor tries again on a later sweep.
fn spawn_worker(
    ctx: &WorkerCtx,
    idx: usize,
    warm: WarmMode,
    ready_tx: Option<SyncSender<Result<(), String>>>,
) -> Result<WorkerSlot, String> {
    let busy_since_us = Arc::new(AtomicU64::new(0));
    let busy = Arc::clone(&busy_since_us);
    let hub = Arc::clone(&ctx.hub);
    let sessions = Arc::clone(&ctx.sessions);
    let cache = Arc::clone(&ctx.cache);
    let compile_cache = Arc::clone(&ctx.compile_cache);
    let decision_cache = Arc::clone(&ctx.decision_cache);
    let bundle = Arc::clone(&ctx.bundle);
    let stop = Arc::clone(&ctx.stop);
    let job_rx = Arc::clone(&ctx.job_rx);
    let policy = ctx.policy;
    let host_fallback = ctx.host_fallback;
    let tracer = ctx.trace.tracer(idx as u32);
    let brownout = ctx.brownout.clone();
    let faults = ctx.faults;
    let store = ctx.store.clone();
    let epoch = ctx.epoch;
    let handle = std::thread::Builder::new()
        .name(format!("qpart-worker-{idx}"))
        .spawn(move || {
            let opts = ServiceOptions {
                compile_cache,
                decision_cache,
                host_fallback,
                tracer: Some(tracer),
                brownout,
                faults,
            };
            let service = Service::with_options(bundle, hub, sessions, cache, opts)
                .map_err(|e| e.to_string());
            let mut service = match service {
                Ok(mut s) => {
                    // warm before reporting ready: serve() returns with
                    // the caches populated, deterministically
                    match warm {
                        WarmMode::Paper => {
                            s.warm_cache();
                        }
                        WarmMode::Log => {
                            if let Some(tier) = &store {
                                s.warm_from_store(tier);
                            }
                        }
                        WarmMode::Off => {}
                    }
                    if let Some(tx) = &ready_tx {
                        let _ = tx.send(Ok(()));
                    }
                    s
                }
                Err(e) => {
                    match &ready_tx {
                        Some(tx) => {
                            let _ = tx.send(Err(format!("worker {idx}: {e}")));
                        }
                        // respawn path: don't hot-loop the supervisor
                        // against a persistently failing init
                        None => std::thread::sleep(Duration::from_millis(100)),
                    }
                    return;
                }
            };
            // Drop our readiness sender now: if another worker panics
            // during init (sending nothing), serve()'s readiness loop
            // must observe disconnection instead of hanging on workers
            // that hold their clones for the whole job loop.
            drop(ready_tx);
            while !stop.load(Ordering::SeqCst) {
                // drain_batch locks the receiver only per dequeue, so
                // a long coalescing window never serializes the pool
                match drain_batch(&job_rx, &policy, Duration::from_millis(100)) {
                    DrainOutcome::Batch(batch) => {
                        // Snapshot the reply sinks before handling: if the
                        // batch panics, every job the worker had not yet
                        // answered gets an `internal` error instead of a
                        // hung connection (the sink's exactly-once latch
                        // makes already-answered jobs a no-op).
                        let sinks: Vec<ReplySink> =
                            batch.iter().map(|j| j.reply.clone()).collect();
                        busy.store(
                            (epoch.elapsed().as_micros() as u64).max(1),
                            Ordering::Relaxed,
                        );
                        let outcome =
                            catch_unwind(AssertUnwindSafe(|| service.handle_batch(batch)));
                        busy.store(0, Ordering::Relaxed);
                        if outcome.is_err() {
                            for sink in sinks {
                                sink.send(WireReply::Msg(Response::Error(ErrorReply {
                                    code: "internal".into(),
                                    message: "inference worker panicked; request abandoned"
                                        .into(),
                                })));
                            }
                            // the service may hold arbitrary partial
                            // state after a panic: die and let the
                            // supervisor respawn a fresh one
                            return;
                        }
                    }
                    DrainOutcome::TimedOut => continue,
                    DrainOutcome::Disconnected => break,
                }
            }
        })
        .map_err(|e| e.to_string())?;
    Ok(WorkerSlot { idx, handle, busy_since_us, flagged_busy_us: 0 })
}

/// One supervisor sweep over the executor pool: run the soft job
/// watchdog (`job_timeouts_total`) and replace dead workers with fresh
/// ones (`worker_restarts_total`). Respawns stop once the server's stop
/// flag is up — exiting workers at shutdown are not "dead".
fn supervise_workers(
    slots: &Mutex<Vec<WorkerSlot>>,
    ctx: &WorkerCtx,
    front: &Metrics,
    job_timeout: Duration,
) {
    let mut slots = slots.lock().unwrap_or_else(|e| e.into_inner());
    let now_us = ctx.epoch.elapsed().as_micros() as u64;
    let timeout_us = job_timeout.as_micros() as u64;
    for slot in slots.iter_mut() {
        if timeout_us > 0 {
            let busy = slot.busy_since_us.load(Ordering::Relaxed);
            if busy != 0
                && now_us.saturating_sub(busy) > timeout_us
                && slot.flagged_busy_us != busy
            {
                // soft watchdog: the batch is not killed (tearing down a
                // mid-execution PJRT call is not recoverable); the
                // counter is the alarm operators page on
                slot.flagged_busy_us = busy;
                Metrics::inc(&front.job_timeouts_total);
            }
        }
        if slot.handle.is_finished() && !ctx.stop.load(Ordering::SeqCst) {
            if let Ok(fresh) = spawn_worker(ctx, slot.idx, WarmMode::Off, None) {
                let dead = std::mem::replace(slot, fresh);
                let _ = dead.handle.join();
                Metrics::inc(&front.worker_restarts_total);
            }
        }
    }
}

/// Spawn the configured front-end; returns the front-end thread and,
/// under the threaded fallback with a scrape listener, the scrape
/// acceptor thread (both joined by [`ServerHandle::shutdown`]).
type FrontendThreads = (JoinHandle<()>, Option<JoinHandle<()>>);

#[allow(clippy::too_many_arguments)]
fn spawn_frontend(
    cfg: &ServerConfig,
    listener: TcpListener,
    metrics_listener: Option<TcpListener>,
    job_tx: SyncSender<Job>,
    hub: Arc<MetricsHub>,
    sessions: Arc<SharedSessionTable>,
    fair: Arc<FairQueue>,
    trace: Arc<TraceSink>,
    recorder: Option<Arc<TrafficRecorder>>,
    stop: Arc<AtomicBool>,
    drain: Arc<AtomicBool>,
) -> Result<FrontendThreads, String> {
    #[cfg(unix)]
    {
        if cfg.frontend == Frontend::Reactor {
            let reactor = crate::net::Reactor::new(crate::net::ReactorParams {
                listener,
                metrics_listener,
                max_conns: cfg.max_conns,
                idle_timeout: cfg.conn_idle,
                binary_allowed: cfg.binary_frames,
                job_tx,
                hub,
                sessions,
                fair,
                trace,
                recorder,
                stop,
                drain,
            })
            .map_err(|e| format!("reactor init: {e}"))?;
            let t = std::thread::Builder::new()
                .name("qpart-reactor".into())
                .spawn(move || reactor.run())
                .map_err(|e| e.to_string())?;
            return Ok((t, None));
        }
    }
    let accept_metrics = hub.front();
    let classes = hub.classes();
    let binary_allowed = cfg.binary_frames;
    let max_conns = cfg.max_conns.max(1);
    let conn_idle = cfg.conn_idle;
    let accept_stop = Arc::clone(&stop);
    let accept_drain = Arc::clone(&drain);
    // one front-end ring shared by every connection thread (SpanRing
    // pushes are mutex-guarded); spans carry FRONT_WORKER like the
    // reactor's so the two front-ends are indistinguishable in a trace
    let front_tracer = trace.tracer(FRONT_WORKER);
    // fair-queue keys for the threaded front-end: a simple accept sequence
    // (the reactor keys buckets by its generation-stamped slot token)
    let conn_seq = Arc::new(std::sync::atomic::AtomicU64::new(0));
    // threaded fallback for the scrape listener: answered inline on the
    // acceptor thread (scrapes are rare and the document is cheap)
    let metrics_thread = match metrics_listener {
        Some(ml) => {
            let scrape_hub = Arc::clone(&hub);
            let scrape_sessions = Arc::clone(&sessions);
            let scrape_stop = Arc::clone(&stop);
            Some(
                std::thread::Builder::new()
                    .name("qpart-metrics-accept".into())
                    .spawn(move || {
                        for stream in ml.incoming() {
                            if scrape_stop.load(Ordering::SeqCst) {
                                break;
                            }
                            let Ok(mut stream) = stream else { continue };
                            // read the scraper's request first and drain
                            // to EOF after replying: closing with unread
                            // bytes would RST the response off the wire
                            let _ = stream
                                .set_read_timeout(Some(Duration::from_millis(500)));
                            let mut sink = [0u8; 2048];
                            let n = stream.read(&mut sink).unwrap_or(0);
                            // route by path (scrape vs /trace endpoints);
                            // a peer that sent nothing gets the default
                            let head = String::from_utf8_lossy(&sink[..n]);
                            let resp = scrape_hub
                                .http_response(request_path(&head), scrape_sessions.len());
                            let _ = stream.write_all(&resp);
                            let _ = stream.shutdown(std::net::Shutdown::Write);
                            while matches!(stream.read(&mut sink), Ok(n) if n > 0) {}
                        }
                    })
                    .map_err(|e| e.to_string())?,
            )
        }
        None => None,
    };
    let accept_thread = std::thread::Builder::new()
        .name("qpart-accept".into())
        .spawn(move || {
            for stream in listener.incoming() {
                if accept_stop.load(Ordering::SeqCst) {
                    break;
                }
                let stream = match stream {
                    Ok(s) => s,
                    Err(_) => continue,
                };
                // request/response protocol: Nagle + delayed-ACK adds
                // ~40-200 ms per round trip without this
                let _ = stream.set_nodelay(true);
                // graceful drain: refuse explicitly, same as the reactor
                if accept_drain.load(Ordering::SeqCst) {
                    Metrics::inc(&accept_metrics.conns_rejected_total);
                    let resp = Response::Error(ErrorReply {
                        code: "draining".into(),
                        message: "server draining: not accepting connections".into(),
                    });
                    let mut stream = stream;
                    let _ = write_frame(&mut stream, &resp.to_line());
                    continue;
                }
                // accept gate: same behavior as the reactor's
                if accept_metrics.conns_open.load(Ordering::Relaxed) >= max_conns as u64 {
                    Metrics::inc(&accept_metrics.conns_rejected_total);
                    let resp = Response::Error(ErrorReply {
                        code: "max_conns".into(),
                        message: "connection limit reached".into(),
                    });
                    let mut stream = stream;
                    let _ = write_frame(&mut stream, &resp.to_line());
                    continue;
                }
                Metrics::inc(&accept_metrics.conns_accepted_total);
                let open = Metrics::gauge_inc(&accept_metrics.conns_open);
                Metrics::observe_peak(&accept_metrics.conns_open_peak, open);
                let job_tx = job_tx.clone();
                let metrics = Arc::clone(&accept_metrics);
                let conn_classes = Arc::clone(&classes);
                let conn_stop = Arc::clone(&accept_stop);
                let conn_drain = Arc::clone(&accept_drain);
                let conn_fair = Arc::clone(&fair);
                let conn_tracer = front_tracer.clone();
                let conn_recorder = recorder.clone();
                let fair_key = conn_seq.fetch_add(1, Ordering::Relaxed);
                let spawned =
                    std::thread::Builder::new().name("qpart-conn".into()).spawn(move || {
                        connection_loop(
                            stream,
                            job_tx,
                            Arc::clone(&metrics),
                            conn_classes,
                            conn_stop,
                            conn_drain,
                            binary_allowed,
                            conn_idle,
                            Arc::clone(&conn_fair),
                            fair_key,
                            conn_tracer,
                            conn_recorder,
                        );
                        conn_fair.forget(fair_key);
                        Metrics::gauge_dec(&metrics.conns_open);
                    });
                if spawned.is_err() {
                    // thread exhaustion: undo the gauge or the max_conns
                    // gate would jam shut on phantom connections
                    Metrics::gauge_dec(&accept_metrics.conns_open);
                }
            }
        })
        .map_err(|e| e.to_string())?;
    Ok((accept_thread, metrics_thread))
}

/// Serialize one reply in the connection's negotiated framing. Segment
/// replies are a splice of the shared encoded body — the payload was
/// serialized once for the whole batch group / cache lifetime. This is
/// the blocking write-then-advance fallback to the reactor's vectored
/// zero-copy egress (`net::reactor::push_reply`): same bytes on the
/// wire, but written through the stream's buffered path.
fn write_reply(
    writer: &mut TcpStream,
    reply: WireReply,
    binary: bool,
) -> Result<(), FrameError> {
    match reply {
        WireReply::Msg(resp) => write_frame(writer, &resp.to_line()),
        WireReply::Segment(s) => {
            // the stamped splice with `None`/`false` is byte-identical to
            // the untraced stamp (proven by the proto splice tests)
            if binary {
                write_binary_frame(
                    writer,
                    &s.body.binary_header_stamped(s.session, s.objective, s.trace, s.degraded),
                    s.body.blob(),
                )
            } else {
                write_frame(
                    writer,
                    &s.body.json_line_stamped(s.session, s.objective, s.trace, s.degraded),
                )
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn connection_loop(
    stream: TcpStream,
    job_tx: SyncSender<Job>,
    metrics: Arc<Metrics>,
    classes: Arc<ClassRegistry>,
    stop: Arc<AtomicBool>,
    drain: Arc<AtomicBool>,
    binary_allowed: bool,
    conn_idle: Duration,
    fair: Arc<FairQueue>,
    fair_key: u64,
    tracer: Tracer,
    recorder: Option<Arc<TrafficRecorder>>,
) {
    // Idle/slow-client timeout via the socket read timeout: the blocking
    // twin of the reactor's idle sweep (a request in flight never trips
    // it — this thread is then parked on the reply channel, not reading).
    // The timeout is capped at a short poll tick so a parked thread
    // notices a drain (or stop) request promptly; a tick that fires
    // before `conn_idle` has really elapsed just re-reads.
    let poll_tick = Duration::from_millis(250);
    let read_timeout = if conn_idle > Duration::ZERO { conn_idle.min(poll_tick) } else { poll_tick };
    let _ = stream.set_read_timeout(Some(read_timeout));
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    // negotiated per session via `hello`; symmetric: grants binary
    // segment replies downlink AND binary activation uploads uplink
    let mut binary = false;
    // per-class counters resolved from the hello's `class` label
    let mut conn_class = None;
    // accept-time sampling, exactly like the reactor's: a sampled trace
    // is server-side only and changes no wire bytes
    let mut conn_trace = tracer.sink().sample_accept();
    let mut last_activity = Instant::now();
    loop {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        if drain.load(Ordering::SeqCst) {
            // graceful drain: whatever was in flight has been answered
            // (the reply write below precedes this check); close now so
            // `conns_open` can reach zero
            break;
        }
        // the read span of a blocking front-end starts when the thread
        // parks on the socket — it includes the wait for the request to
        // arrive (the thread cannot observe first-byte time separately)
        let t_read = conn_trace.map(|_| tracer.now_us());
        let frame = match read_any_frame(&mut reader) {
            Ok(f) => {
                last_activity = Instant::now();
                f
            }
            Err(FrameError::Closed) => break,
            Err(FrameError::Io(e))
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                // a poll tick, not necessarily the idle bound: only a
                // connection quiet for the full `conn_idle` is reaped
                if conn_idle > Duration::ZERO && last_activity.elapsed() >= conn_idle {
                    Metrics::inc(&metrics.conns_timed_out);
                    break;
                }
                continue;
            }
            Err(e) => {
                Metrics::inc(&metrics.errors_total);
                let resp = Response::Error(ErrorReply {
                    code: "bad_frame".into(),
                    message: e.to_string(),
                });
                let _ = write_frame(&mut writer, &resp.to_line());
                break;
            }
        };
        // a binary request frame is only valid after a granted hello —
        // the server must not silently accept what it did not grant
        if matches!(frame, Frame::Binary(_)) && !binary {
            Metrics::inc(&metrics.errors_total);
            let resp = Response::Error(ErrorReply {
                code: "bad_frame".into(),
                message: "binary frame before negotiation (send hello first)".into(),
            });
            if write_frame(&mut writer, &resp.to_line()).is_err() {
                break;
            }
            continue;
        }
        let req = match Request::from_frame(&frame) {
            Ok(r) => r,
            Err(e) => {
                Metrics::inc(&metrics.errors_total);
                let resp = Response::Error(ErrorReply {
                    code: "bad_request".into(),
                    message: e.to_string(),
                });
                if write_frame(&mut writer, &resp.to_line()).is_err() {
                    break;
                }
                continue;
            }
        };
        // framing negotiation is connection state — answered here, never
        // queued (the hello reply itself is always a JSON frame); counted
        // in the front-end's metrics so protocol traffic still adds up
        if let Request::Hello(h) = &req {
            Metrics::inc(&metrics.requests_total);
            binary = h.binary_frames && binary_allowed;
            // class-weighted fair queuing: scale this connection's
            // token-bucket rate by the declared class weight (clamped
            // inside; no-op while the limiter is disabled)
            fair.set_weight(fair_key, h.weight);
            // resolve the class label once: every job this connection
            // submits carries the counter handle, so per-class
            // throttle/shed/degrade attribution is lock-free per event
            conn_class =
                if h.class.is_empty() { None } else { Some(classes.class(&h.class)) };
            if h.trace {
                // hello-negotiated grant: the id is echoed on the wire
                // for client-side correlation (supersedes any sampled
                // trace this connection drew at accept)
                conn_trace = Some(tracer.sink().grant());
            }
            let resp = Response::Hello(HelloReply {
                binary_frames: binary,
                trace: conn_trace.and_then(JobTrace::wire_id),
            });
            if write_frame(&mut writer, &resp.to_line()).is_err() {
                break;
            }
            continue;
        }
        // fair queuing: refuse before the job occupies queue capacity
        if fair.enabled() && !fair.try_admit(fair_key) {
            Metrics::inc(&metrics.sched_throttled_total);
            if let Some(c) = &conn_class {
                Metrics::inc(&c.sched_throttled_total);
            }
            let resp = Response::Error(ErrorReply {
                code: "throttled".into(),
                message: "fair queuing: per-connection rate exceeded".into(),
            });
            if write_frame(&mut writer, &resp.to_line()).is_err() {
                break;
            }
            continue;
        }
        // recorder payload pulled out before the request moves into the
        // job; only admitted requests are recorded (a shed request never
        // reached the service, so a replay should not send it either)
        let rec_infer = match &req {
            Request::Infer(i) if recorder.is_some() => {
                Some((i.accuracy_budget, i.channel_capacity_bps))
            }
            _ => None,
        };
        let rec_upload = recorder.is_some() && matches!(req, Request::Activation(_));
        let (reply_tx, reply_rx) = sync_channel::<StampedReply>(1);
        let (reply, stamp) = match job_tx.try_send(
            Job::new(req, reply_tx).with_trace(conn_trace).with_class(conn_class.clone()),
        ) {
            Ok(()) => {
                if let Some(rec) = &recorder {
                    if let Some((budget, cap)) = rec_infer {
                        rec.record_infer(fair_key, budget, cap);
                    } else if rec_upload {
                        rec.record_upload(fair_key);
                    }
                }
                if let (Some(trace), Some(start)) = (conn_trace, t_read) {
                    // read span (wait + frame assembly), then the admit
                    // span for the queue hand-off — both closing now,
                    // mirroring the reactor's stages
                    let now = tracer.now_us();
                    tracer.span(trace, Stage::Read, start, now);
                    tracer.span(trace, Stage::Admit, now, now);
                }
                match reply_rx.recv() {
                    Ok(r) => r,
                    Err(_) => (
                        WireReply::Msg(Response::Error(ErrorReply {
                            code: "internal".into(),
                            message: "inference worker gone".into(),
                        })),
                        None,
                    ),
                }
            }
            Err(TrySendError::Full(_)) => {
                Metrics::inc(&metrics.shed_total);
                (
                    WireReply::Msg(Response::Error(ErrorReply {
                        code: "overloaded".into(),
                        message: "admission control: job queue full".into(),
                    })),
                    None,
                )
            }
            Err(TrySendError::Disconnected(_)) => (
                WireReply::Msg(Response::Error(ErrorReply {
                    code: "shutdown".into(),
                    message: "server stopping".into(),
                })),
                None,
            ),
        };
        let t_route = stamp.map(|s| {
            // route span: worker pushed the reply → this thread resumed
            let now = tracer.now_us();
            tracer.span(s.trace, Stage::Route, s.pushed_us, now);
            (s.trace, now)
        });
        if write_reply(&mut writer, reply, binary).is_err() {
            break;
        }
        if let Some((trace, start)) = t_route {
            // flush span: serialization + the blocking socket write
            tracer.span(trace, Stage::Flush, start, tracer.now_us());
        }
    }
}
