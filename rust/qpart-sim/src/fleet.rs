//! Discrete-event fleet simulation: the dynamic-workload-balancing
//! experiment (`qpart sim`, the `edge_fleet` example, Fig. 5 dynamics).
//!
//! Ties the three §V modules together: for each arriving request the
//! server runs the online algorithm (Algorithm 2) against the device's
//! *currently observed* channel and its compute profile, then the request
//! flows downlink → device compute → uplink → server compute through the
//! executing/communication modules, and the performance module records it.

use crate::comm::LinkSim;
use crate::device::{DeviceSim, ServerSim};
use crate::perf::{PerfCollector, RequestRecord};
use crate::workload::{DeviceClass, WorkloadConfig, WorkloadGen};
use qpart_core::channel::FadingChannel;
use qpart_core::cost::{CostModel, ServerProfile, TradeoffWeights};
use qpart_core::model::ModelSpec;
use qpart_core::optimizer::{serve_request, RequestParams};
use qpart_core::quant::PatternSet;
use qpart_core::Result;

/// Fleet-simulation configuration.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    pub workload: WorkloadConfig,
    /// Server slots (parallel executors).
    pub server_slots: usize,
    /// Mean SNR of device links (linear). Channel bandwidth is fixed at
    /// 20 MHz; large-scale gain is chosen so mean capacity ≈ the paper's
    /// 200 Mbps when `mean_snr` ≈ 1000.
    pub mean_snr: f64,
    /// Fading coherence period (s); ∞ disables fading.
    pub coherence_s: f64,
    /// Planning overhead charged per request (s) — Algorithm 2 is a table
    /// lookup + L objective evaluations; measured ~1 µs, charged here.
    pub plan_overhead_s: f64,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            workload: WorkloadConfig::default(),
            server_slots: 4,
            mean_snr: 1000.0,
            coherence_s: 0.5,
            plan_overhead_s: 5e-6,
        }
    }
}

/// Simulation output: collector + balance diagnostics.
#[derive(Debug)]
pub struct FleetReport {
    pub perf: PerfCollector,
    /// Requests rejected (infeasible accuracy/memory).
    pub rejected: usize,
    /// Total server billed cost.
    pub server_cost: f64,
    /// Per-device energy totals (J).
    pub device_energy_j: Vec<f64>,
}

/// Run the fleet simulation for one model + offline pattern set.
pub fn run_fleet(
    model: &ModelSpec,
    patterns: &PatternSet,
    classes: &[DeviceClass],
    cfg: &FleetConfig,
) -> Result<FleetReport> {
    let mut gen = WorkloadGen::new(cfg.workload.clone(), classes);
    let events = gen.events();

    let mut devices: Vec<DeviceSim> = gen
        .devices
        .iter()
        .enumerate()
        .map(|(i, (p, _))| DeviceSim::new(i, *p))
        .collect();
    // per-device fading links; bandwidth 20 MHz, alpha tuned to mean_snr
    let bandwidth = 20e6;
    let mut links: Vec<LinkSim> = (0..devices.len())
        .map(|i| {
            let fading = FadingChannel::new(
                bandwidth,
                cfg.mean_snr,
                1.0, // unit noise power; alpha carries the SNR
                1.0,
                cfg.workload.seed ^ 0x11CC_0000 ^ (i as u64).wrapping_mul(0x9E37),
            );
            LinkSim::fading(fading, cfg.coherence_s)
        })
        .collect();
    let mut server = ServerSim::with_slots(ServerProfile::paper_default(), cfg.server_slots);
    let mut perf = PerfCollector::new();
    let mut rejected = 0usize;

    for ev in events {
        let dev = &mut devices[ev.device];
        let link = &mut links[ev.device];
        let observed = link.observe(ev.arrival_s);
        let cost_model = CostModel {
            device: dev.profile,
            server: server.profile,
            channel: observed,
            weights: TradeoffWeights::paper_default(),
        };
        let req = RequestParams { cost: cost_model, accuracy_budget: ev.accuracy_budget };
        let decision = match serve_request(model, patterns, &req) {
            Ok(d) => d,
            Err(_) => {
                rejected += 1;
                continue;
            }
        };
        let pat = &decision.pattern;
        let p = pat.partition;
        let t_plan_done = ev.arrival_s + cfg.plan_overhead_s + server.queue_delay(ev.arrival_s);

        // downlink: quantized weights
        let w_bits: u64 = pat
            .weight_bits
            .iter()
            .enumerate()
            .map(|(i, &b)| (b as u64) * model.weight_params(i + 1))
            .sum();
        let t_down = if w_bits > 0 { link.transfer(t_plan_done, w_bits) } else { t_plan_done };
        // device compute
        let t_dev = if p > 0 { dev.compute(t_down, model.device_macs(p)) } else { t_down };
        // uplink: quantized activation
        let a_bits = (pat.activation_bits as u64) * model.activation_elems(p);
        let t_up = link.transfer(t_dev, a_bits);
        // server compute
        let t_srv = if p < model.num_layers() {
            server.compute(t_up, model.server_macs(p))
        } else {
            t_up
        };

        perf.push(RequestRecord {
            device: ev.device,
            model: model.name.clone(),
            arrival_s: ev.arrival_s,
            done_s: t_srv,
            plan_s: t_plan_done - ev.arrival_s,
            downlink_s: t_down - t_plan_done,
            device_compute_s: t_dev - t_down,
            uplink_s: t_up - t_dev,
            server_compute_s: t_srv - t_up,
            device_energy_j: dev.profile.compute_energy_j(model.device_macs(p))
                + observed.tx_energy_j(a_bits),
            payload_bits: w_bits + a_bits,
            partition: p,
            objective: decision.cost.objective,
        });
    }

    Ok(FleetReport {
        rejected,
        server_cost: server.billed_cost,
        device_energy_j: devices.iter().map(|d| d.energy_j).collect(),
        perf,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use qpart_core::accuracy::CalibrationTable;
    use qpart_core::model::mlp6;
    use qpart_core::optimizer::{offline_quantize, OfflineConfig};

    const LEVELS: [f64; 5] = [0.0025, 0.005, 0.01, 0.02, 0.05];

    fn setup() -> (ModelSpec, PatternSet) {
        let m = mlp6();
        let c = CalibrationTable::synthetic(&m, &LEVELS, 51);
        let set = offline_quantize(&m, &c, OfflineConfig::default()).unwrap();
        (m, set)
    }

    #[test]
    fn fleet_serves_all_requests() {
        let (m, set) = setup();
        let cfg = FleetConfig::default();
        let report = run_fleet(&m, &set, &DeviceClass::default_fleet(), &cfg).unwrap();
        assert!(report.perf.records.len() > 50, "{}", report.perf.records.len());
        assert_eq!(report.rejected, 0);
        let lat = report.perf.latency();
        assert!(lat.mean > 0.0 && lat.mean.is_finite());
        assert!(report.server_cost >= 0.0);
    }

    #[test]
    fn deterministic() {
        let (m, set) = setup();
        let cfg = FleetConfig::default();
        let a = run_fleet(&m, &set, &DeviceClass::default_fleet(), &cfg).unwrap();
        let b = run_fleet(&m, &set, &DeviceClass::default_fleet(), &cfg).unwrap();
        assert_eq!(a.perf.records.len(), b.perf.records.len());
        assert_eq!(a.perf.latency(), b.perf.latency());
    }

    #[test]
    fn slow_links_push_partitions_down() {
        // Workload balancing in action: with a terrible channel the online
        // algorithm should avoid shipping weights (small partitions).
        let (m, set) = setup();
        let mut cfg = FleetConfig { mean_snr: 0.02, ..Default::default() };
        cfg.workload.duration_s = 5.0;
        let bad = run_fleet(&m, &set, &DeviceClass::default_fleet(), &cfg).unwrap();
        let mut cfg2 = FleetConfig { mean_snr: 1e6, ..Default::default() };
        cfg2.workload.duration_s = 5.0;
        let good = run_fleet(&m, &set, &DeviceClass::default_fleet(), &cfg2).unwrap();
        let mean_p = |r: &FleetReport| {
            r.perf.records.iter().map(|x| x.partition as f64).sum::<f64>()
                / r.perf.records.len() as f64
        };
        assert!(
            mean_p(&bad) <= mean_p(&good) + 1e-9,
            "bad-channel mean partition {} vs good {}",
            mean_p(&bad),
            mean_p(&good)
        );
    }

    #[test]
    fn saturation_raises_latency() {
        let (m, set) = setup();
        let mut low = FleetConfig::default();
        low.workload.arrival_rate = 5.0;
        low.workload.duration_s = 5.0;
        let mut high = FleetConfig::default();
        high.workload.arrival_rate = 500.0;
        high.workload.duration_s = 5.0;
        let a = run_fleet(&m, &set, &DeviceClass::default_fleet(), &low).unwrap();
        let b = run_fleet(&m, &set, &DeviceClass::default_fleet(), &high).unwrap();
        assert!(b.perf.latency().p95 >= a.perf.latency().p95);
    }
}
