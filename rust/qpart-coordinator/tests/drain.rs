//! Graceful-drain integration tests: once drain begins, in-flight work
//! finishes and its replies are flushed, new connections are refused with
//! a `draining` error, and every connection is released — on both the
//! reactor and the thread-per-connection front-ends. No PJRT required
//! (synthetic bundle, host-fallback phase 2).

use qpart_coordinator::client::paper_request;
use qpart_coordinator::testing::{synthetic_bundle, synthetic_upload, tiny_arch};
use qpart_coordinator::{serve, FaultSpec, Frontend, ServerConfig};
use qpart_proto::frame::{read_frame, write_frame};
use qpart_proto::messages::{Request, Response};
use std::io::BufReader;
use std::net::TcpStream;
use std::time::{Duration, Instant};

/// Poll `f` until it returns true or `deadline` elapses.
fn wait_until<F: Fn() -> bool>(deadline: Duration, f: F) -> bool {
    let t0 = Instant::now();
    while t0.elapsed() < deadline {
        if f() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    f()
}

/// The shared drain scenario: start a phase-2 round trip, flip the server
/// into drain mode while the worker is still executing it (an injected
/// 300ms batch delay guarantees the overlap), and assert the reply still
/// arrives, new dials are refused with `draining`, and the server reaches
/// zero open connections within the drain timeout.
fn drain_finishes_in_flight_and_refuses_new(frontend: Frontend, tag: &str) {
    let dir = synthetic_bundle(tag);
    let handle = serve(ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers: 1,
        frontend,
        host_fallback: true,
        // slow the executor down so drain provably begins with the
        // upload still in flight
        fault_inject: Some(FaultSpec { exec_delay_ms: 300, ..FaultSpec::default() }),
        artifacts_dir: dir.to_str().unwrap().to_string(),
        ..ServerConfig::default()
    })
    .unwrap();
    let addr = handle.addr.to_string();
    let arch = tiny_arch();

    // phase 1 completes before the drain...
    let raw = TcpStream::connect(&addr).unwrap();
    raw.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut w = raw.try_clone().unwrap();
    let mut reader = BufReader::new(raw);
    write_frame(&mut w, &Request::Infer(paper_request("tinymlp", 0.02)).to_line()).unwrap();
    let reply = match Response::from_line(&read_frame(&mut reader).unwrap()).unwrap() {
        Response::Segment(r) => r,
        other => panic!("unexpected {other:?}"),
    };

    // ...then the phase-2 upload goes out, and drain begins while the
    // delayed worker is still chewing on it
    let upload = synthetic_upload(&reply, &arch, 4242);
    write_frame(&mut w, &Request::Activation(upload).to_line()).unwrap();
    std::thread::sleep(Duration::from_millis(100)); // front-end has read the frame
    handle.begin_drain();
    assert!(handle.draining());

    // a fresh dial is refused with a soft `draining` error, not a hang
    // or a silent close
    let refused = TcpStream::connect(&addr).unwrap();
    refused.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut refused_reader = BufReader::new(refused);
    match Response::from_line(&read_frame(&mut refused_reader).unwrap()).unwrap() {
        Response::Error(e) => assert_eq!(e.code, "draining", "{}", e.message),
        other => panic!("drain refusal expected, got {other:?}"),
    }

    // the in-flight phase-2 reply is still delivered before the close
    match Response::from_line(&read_frame(&mut reader).unwrap()).unwrap() {
        Response::Result(r) => assert!(r.logits.iter().all(|l| l.is_finite())),
        other => panic!("in-flight reply lost to drain: {other:?}"),
    }

    // once quiescent, the server hangs up on its own and `drain` reports
    // a clean exit with zero open connections
    assert!(
        wait_until(Duration::from_secs(10), || handle.snapshot().conns_open == 0),
        "draining server kept {} conns open",
        handle.snapshot().conns_open
    );
    assert!(handle.drain(Duration::from_secs(10)), "drain timed out with conns open");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn reactor_drain_finishes_in_flight_work_and_refuses_new_connections() {
    drain_finishes_in_flight_and_refuses_new(Frontend::Reactor, "drain-reactor");
}

#[test]
fn threaded_drain_finishes_in_flight_work_and_refuses_new_connections() {
    drain_finishes_in_flight_and_refuses_new(Frontend::Threaded, "drain-threaded");
}

#[test]
fn drain_with_no_traffic_exits_immediately_and_clean() {
    let dir = synthetic_bundle("drain-idle");
    let handle = serve(ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers: 1,
        artifacts_dir: dir.to_str().unwrap().to_string(),
        ..ServerConfig::default()
    })
    .unwrap();
    assert!(!handle.draining());
    let t0 = Instant::now();
    assert!(handle.drain(Duration::from_secs(5)), "idle drain was not clean");
    assert!(t0.elapsed() < Duration::from_secs(5), "idle drain burned its whole timeout");
    let _ = std::fs::remove_dir_all(&dir);
}
