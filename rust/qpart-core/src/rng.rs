//! Deterministic PRNG + distributions (no `rand` crate offline).
//!
//! [`Rng`] is xoshiro256** seeded through SplitMix64 — the standard
//! recommendation for reproducible simulation. QPART uses it for the fading
//! channel (exponential small-scale fading, paper Eq. 11), synthetic
//! workload arrivals, and the property-test harness.

/// xoshiro256** PRNG with SplitMix64 seeding.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed deterministically from a single u64 (SplitMix64 expansion).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Next raw u64.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits → uniform double in [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). Panics if n == 0.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "Rng::below(0)");
        // Lemire-style rejection to remove modulo bias.
        let threshold = n.wrapping_neg() % n;
        loop {
            let r = self.next_u64();
            if r >= threshold {
                return r % n;
            }
        }
    }

    /// Uniform usize in [lo, hi).
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn gaussian(&mut self) -> f64 {
        // avoid ln(0)
        let u1 = 1.0 - self.uniform();
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with the given mean (unit-mean fading uses mean = 1).
    pub fn exponential(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.uniform();
        -mean * u.ln()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a random element by reference.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range_usize(0, xs.len())]
    }

    /// Fork a child stream (distinct, deterministic).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// Labeled substream: a child stream derived from `seed` and a static
    /// label rather than from draw order. Two call sites using different
    /// labels get independent streams that stay stable even when the number
    /// of draws at *other* call sites changes (e.g. adding a device class
    /// must not perturb arrival times).
    pub fn from_label(seed: u64, label: &str) -> Rng {
        // FNV-1a over the label bytes, folded into the seed.
        let mut h: u64 = 0xCBF2_9CE4_8422_2325;
        for &b in label.as_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
        Rng::new(seed ^ h)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_in_range_and_mean() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn below_unbiased_small() {
        let mut r = Rng::new(3);
        let mut counts = [0u32; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.gaussian();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.exponential(2.0);
            assert!(x >= 0.0);
            sum += x;
        }
        assert!((sum / n as f64 - 2.0).abs() < 0.1);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>(), "shuffle should move something");
    }

    #[test]
    fn labeled_streams_independent() {
        // Same seed, different labels → different streams; same label →
        // identical stream regardless of what other streams were drawn.
        let mut a = Rng::from_label(42, "arrivals");
        let mut b = Rng::from_label(42, "classes");
        assert_ne!(a.next_u64(), b.next_u64());
        let mut c = Rng::from_label(42, "arrivals");
        let mut d = Rng::from_label(42, "arrivals");
        for _ in 0..16 {
            assert_eq!(c.next_u64(), d.next_u64());
        }
        assert_ne!(
            Rng::from_label(1, "arrivals").next_u64(),
            Rng::from_label(2, "arrivals").next_u64()
        );
    }

    #[test]
    fn fork_streams_diverge() {
        let mut a = Rng::new(9);
        let mut f1 = a.fork();
        let mut f2 = a.fork();
        assert_ne!(f1.next_u64(), f2.next_u64());
    }
}
