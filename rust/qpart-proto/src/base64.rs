//! Minimal base64 (standard alphabet, padded) — used to embed bit-packed
//! segment payloads in JSON-lines frames. No external crates offline.

const ALPHABET: &[u8; 64] = b"ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz0123456789+/";

/// Encode bytes to standard padded base64.
pub fn encode(data: &[u8]) -> String {
    let mut out = String::with_capacity(data.len().div_ceil(3) * 4);
    for chunk in data.chunks(3) {
        let b = [chunk[0], *chunk.get(1).unwrap_or(&0), *chunk.get(2).unwrap_or(&0)];
        let n = ((b[0] as u32) << 16) | ((b[1] as u32) << 8) | b[2] as u32;
        out.push(ALPHABET[(n >> 18) as usize & 63] as char);
        out.push(ALPHABET[(n >> 12) as usize & 63] as char);
        out.push(if chunk.len() > 1 { ALPHABET[(n >> 6) as usize & 63] as char } else { '=' });
        out.push(if chunk.len() > 2 { ALPHABET[n as usize & 63] as char } else { '=' });
    }
    out
}

/// Decode standard base64 (padded or unpadded). Rejects invalid characters.
pub fn decode(s: &str) -> Result<Vec<u8>, String> {
    fn val(c: u8) -> Result<u32, String> {
        match c {
            b'A'..=b'Z' => Ok((c - b'A') as u32),
            b'a'..=b'z' => Ok((c - b'a' + 26) as u32),
            b'0'..=b'9' => Ok((c - b'0' + 52) as u32),
            b'+' => Ok(62),
            b'/' => Ok(63),
            _ => Err(format!("invalid base64 byte {c:#x}")),
        }
    }
    let bytes: Vec<u8> = s.bytes().filter(|&b| b != b'=').collect();
    if s.bytes().any(|b| b == b'=')
        && !s.trim_end_matches('=').bytes().all(|b| b != b'=')
    {
        return Err("padding in the middle".into());
    }
    if bytes.len() % 4 == 1 {
        return Err("invalid base64 length".into());
    }
    let mut out = Vec::with_capacity(bytes.len() * 3 / 4);
    for chunk in bytes.chunks(4) {
        let mut n = 0u32;
        for (i, &c) in chunk.iter().enumerate() {
            n |= val(c)? << (18 - 6 * i);
        }
        out.push((n >> 16) as u8);
        if chunk.len() > 2 {
            out.push((n >> 8) as u8);
        }
        if chunk.len() > 3 {
            out.push(n as u8);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        assert_eq!(encode(b""), "");
        assert_eq!(encode(b"f"), "Zg==");
        assert_eq!(encode(b"fo"), "Zm8=");
        assert_eq!(encode(b"foo"), "Zm9v");
        assert_eq!(encode(b"foobar"), "Zm9vYmFy");
        assert_eq!(decode("Zm9vYmFy").unwrap(), b"foobar");
        assert_eq!(decode("Zg==").unwrap(), b"f");
        assert_eq!(decode("Zg").unwrap(), b"f");
    }

    #[test]
    fn roundtrip_all_bytes() {
        let data: Vec<u8> = (0..=255u8).collect();
        assert_eq!(decode(&encode(&data)).unwrap(), data);
    }

    #[test]
    fn roundtrip_various_lengths() {
        for n in 0..50usize {
            let data: Vec<u8> = (0..n as u8).map(|i| i.wrapping_mul(37)).collect();
            assert_eq!(decode(&encode(&data)).unwrap(), data, "len {n}");
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(decode("!!!!").is_err());
        assert!(decode("AAAAA").is_err()); // length ≡ 1 mod 4
        assert!(decode("Z=g=").is_err());
    }
}
