//! Tiny property-testing harness (proptest is unavailable offline).
//!
//! [`check`] runs a property over `n` deterministic random cases; on failure
//! it reports the failing case index and seed so the case can be replayed
//! exactly. Generators are plain closures over [`Rng`], composed by hand —
//! adequate for the invariants QPART tests (round-trips, monotonicity,
//! conservation laws).
//!
//! ```
//! use qpart_core::testing::check;
//! check("addition commutes", 100, |rng| {
//!     let (a, b) = (rng.uniform(), rng.uniform());
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::rng::Rng;

/// Base seed for all property runs; change `QPART_PROP_SEED` env var to
/// explore a different stream without recompiling.
fn base_seed() -> u64 {
    std::env::var("QPART_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5EED_CAFE_F00D_0001)
}

/// Number of cases multiplier (set `QPART_PROP_CASES` to scale up locally).
fn case_multiplier() -> usize {
    std::env::var("QPART_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1)
}

/// Run `prop` for `n` cases with independent deterministic RNG streams.
/// Panics (with the case seed) on the first failing case.
pub fn check<F>(name: &str, n: usize, mut prop: F)
where
    F: FnMut(&mut Rng),
{
    let seed = base_seed();
    let total = n * case_multiplier();
    for case in 0..total {
        let case_seed = seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {case}/{total} (case_seed={case_seed:#x}): {msg}"
            );
        }
    }
}

/// Replay a single failing case by its reported seed.
pub fn replay<F>(case_seed: u64, mut prop: F)
where
    F: FnMut(&mut Rng),
{
    let mut rng = Rng::new(case_seed);
    prop(&mut rng);
}

/// Generate a random `Vec<f32>` with values in [lo, hi).
pub fn vec_f32(rng: &mut Rng, len: usize, lo: f32, hi: f32) -> Vec<f32> {
    (0..len)
        .map(|_| rng.range_f64(lo as f64, hi as f64) as f32)
        .collect()
}

/// Assert two floats are within `tol` (absolute) or `rel` (relative).
#[track_caller]
pub fn assert_close(a: f64, b: f64, tol: f64, rel: f64) {
    let diff = (a - b).abs();
    let scale = a.abs().max(b.abs());
    assert!(
        diff <= tol + rel * scale,
        "not close: a={a} b={b} diff={diff} (tol={tol}, rel={rel})"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("counts", 50, |_| count += 1);
        assert_eq!(count, 50 * case_multiplier());
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_reports_seed() {
        check("fails", 10, |rng| {
            assert!(rng.uniform() < 0.5, "too big");
        });
    }

    #[test]
    fn assert_close_accepts_and_rejects() {
        assert_close(1.0, 1.0 + 1e-12, 1e-9, 0.0);
        let r = std::panic::catch_unwind(|| assert_close(1.0, 2.0, 1e-9, 1e-9));
        assert!(r.is_err());
    }
}
