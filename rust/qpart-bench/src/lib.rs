//! Tiny micro-benchmark harness (criterion is unavailable offline).
//!
//! Two halves:
//! * [`time_it`] / [`BenchStats`] — warmup + timed iterations with
//!   mean/p50/p99, for the `perf_*` benches.
//! * [`Table`] — aligned table printing for the paper-figure/table
//!   benches, so each bench binary prints the same rows/series the paper
//!   reports (and optionally CSV via `QPART_BENCH_CSV=1`).

use std::hint::black_box as std_black_box;
use std::time::{Duration, Instant};

/// Re-export of `std::hint::black_box` for benchmark bodies.
pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// Timing statistics over benchmark iterations.
#[derive(Debug, Clone, Copy)]
pub struct BenchStats {
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
    pub total: Duration,
}

impl BenchStats {
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }

    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    /// Throughput given `units` of work per iteration.
    pub fn per_second(&self, units: f64) -> f64 {
        units / (self.mean_ns / 1e9)
    }
}

/// Benchmark `f`: `warmup` untimed runs, then timed runs until both
/// `min_iters` iterations and `min_time` have elapsed (whichever is later,
/// capped at `max_iters`).
pub fn time_it<F: FnMut()>(warmup: usize, min_iters: usize, min_time: Duration, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let max_iters = min_iters.max(1) * 1000;
    let mut samples_ns: Vec<f64> = Vec::with_capacity(min_iters);
    let start = Instant::now();
    loop {
        let t0 = Instant::now();
        f();
        samples_ns.push(t0.elapsed().as_nanos() as f64);
        let done_iters = samples_ns.len() >= min_iters;
        let done_time = start.elapsed() >= min_time;
        if (done_iters && done_time) || samples_ns.len() >= max_iters {
            break;
        }
    }
    let total = start.elapsed();
    let mut sorted = samples_ns.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q = |p: f64| sorted[((sorted.len() as f64 - 1.0) * p).round() as usize];
    BenchStats {
        iters: samples_ns.len(),
        mean_ns: samples_ns.iter().sum::<f64>() / samples_ns.len() as f64,
        p50_ns: q(0.50),
        p99_ns: q(0.99),
        min_ns: sorted[0],
        total,
    }
}

/// Quick preset: 3 warmups, ≥30 iters, ≥200 ms.
pub fn quick<F: FnMut()>(f: F) -> BenchStats {
    time_it(3, 30, Duration::from_millis(200), f)
}

/// Format nanoseconds human-readably.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Aligned-table printer for figure/table benches.
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Table {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Print aligned; also CSV when `QPART_BENCH_CSV=1`.
    pub fn print(&self) {
        println!("\n== {} ==", self.title);
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", line(&self.headers));
        println!("{}", widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("  "));
        for row in &self.rows {
            println!("{}", line(row));
        }
        if std::env::var("QPART_BENCH_CSV").as_deref() == Ok("1") {
            println!("csv,{}", self.headers.join(","));
            for row in &self.rows {
                println!("csv,{}", row.join(","));
            }
        }
    }
}

/// Format helpers used across bench binaries.
pub fn fmt_bits(bits: u64) -> String {
    let bytes = bits as f64 / 8.0;
    if bytes < 1024.0 {
        format!("{bytes:.0} B")
    } else if bytes < 1024.0 * 1024.0 {
        format!("{:.1} KiB", bytes / 1024.0)
    } else {
        format!("{:.2} MiB", bytes / (1024.0 * 1024.0))
    }
}

pub fn fmt_si(x: f64) -> String {
    let ax = x.abs();
    if ax == 0.0 {
        "0".into()
    } else if ax < 1e-6 {
        format!("{:.2} n", x * 1e9)
    } else if ax < 1e-3 {
        format!("{:.2} µ", x * 1e6)
    } else if ax < 1.0 {
        format!("{:.2} m", x * 1e3)
    } else if ax < 1e3 {
        format!("{x:.3}")
    } else if ax < 1e6 {
        format!("{:.2} k", x / 1e3)
    } else if ax < 1e9 {
        format!("{:.2} M", x / 1e6)
    } else {
        format!("{:.2} G", x / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_counts() {
        let mut n = 0u64;
        let stats = time_it(2, 10, Duration::from_millis(1), || {
            n += 1;
            black_box(n);
        });
        assert!(stats.iters >= 10);
        assert!(n as usize >= stats.iters + 2);
        assert!(stats.mean_ns > 0.0);
        assert!(stats.p99_ns >= stats.p50_ns);
        assert!(stats.min_ns <= stats.p50_ns);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert!(fmt_ns(2_500.0).contains("µs"));
        assert!(fmt_ns(2.5e6).contains("ms"));
        assert!(fmt_bits(8 * 2048).contains("KiB"));
        assert!(fmt_si(2.5e-6).contains('µ'));
    }

    #[test]
    fn table_prints() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        t.print(); // should not panic
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn table_rejects_bad_rows() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(vec!["1".into()]);
    }
}
