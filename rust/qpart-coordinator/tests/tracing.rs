//! Tracing-layer integration tests: a traced two-phase request yields a
//! complete, monotonic span timeline (queryable through the handle and
//! the `/trace` HTTP endpoints), the reactor and threaded front-ends
//! emit the same stage vocabulary, sampling disabled stays inert on the
//! wire and in the store, and the slow-exemplar store keeps exactly N
//! worst timelines under live traffic. No PJRT required (synthetic
//! bundle + host-fallback phase 2).

use qpart_coordinator::client::paper_request;
use qpart_coordinator::testing::{synthetic_bundle, synthetic_upload, tiny_arch, BlockingConn};
use qpart_coordinator::{serve, Frontend, ServerConfig, ServerHandle};
use qpart_core::json::{parse, Value};
use qpart_proto::messages::{HelloRequest, Request, Response};
use std::collections::BTreeSet;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

/// All eight pipeline stages a traced two-phase exchange must cover
/// (phase 1 contributes plan/encode, phase 2 contributes execute).
const ALL_STAGES: [&str; 8] =
    ["read", "admit", "queue_wait", "plan", "encode", "execute", "route", "flush"];

/// Poll `f` until it returns true or `deadline` elapses (late spans —
/// e.g. the flush span of the reply the client just read — land on the
/// server's next instruction, not synchronously with the client).
fn wait_until<F: Fn() -> bool>(deadline: Duration, f: F) -> bool {
    let t0 = Instant::now();
    while t0.elapsed() < deadline {
        if f() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    f()
}

/// One-shot HTTP/1.0 GET against the metrics listener: (status, body).
fn http_get(addr: std::net::SocketAddr, path: &str) -> (String, String) {
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    s.write_all(format!("GET {path} HTTP/1.0\r\n\r\n").as_bytes()).unwrap();
    let mut raw = String::new();
    let _ = s.read_to_string(&mut raw); // server closes when flushed
    let (head, body) = raw.split_once("\r\n\r\n").expect("HTTP header/body split");
    (head.lines().next().unwrap_or_default().to_string(), body.to_string())
}

/// Run hello(trace) → infer → activation on one connection and return
/// the granted trace id, asserting both replies echo it.
fn traced_two_phase(addr: &str) -> u64 {
    let mut conn = BlockingConn::connect(addr).unwrap();
    let hello = Request::Hello(HelloRequest { trace: true, ..HelloRequest::default() });
    let id = match conn.call(&hello).unwrap() {
        Response::Hello(h) => h.trace.expect("hello grants a trace id"),
        other => panic!("unexpected {other:?}"),
    };
    let reply = match conn.call(&Request::Infer(paper_request("tinymlp", 0.02))).unwrap() {
        Response::Segment(r) => r,
        other => panic!("unexpected {other:?}"),
    };
    assert_eq!(reply.trace, Some(id), "segment reply echoes the granted id");
    let upload = synthetic_upload(&reply, &tiny_arch(), 7);
    match conn.call(&Request::Activation(upload)).unwrap() {
        Response::Result(r) => assert_eq!(r.trace, Some(id), "result echoes the granted id"),
        other => panic!("unexpected {other:?}"),
    }
    id
}

/// `(stage, start_us, end_us)` rows of a timeline JSON, in wire order.
fn timeline_spans(timeline: &Value) -> Vec<(String, u64, u64)> {
    timeline
        .req_arr("spans")
        .unwrap()
        .iter()
        .map(|s| {
            (
                s.req_str("stage").unwrap().to_string(),
                s.req_u64("start_us").unwrap(),
                s.req_u64("end_us").unwrap(),
            )
        })
        .collect()
}

fn stage_set(spans: &[(String, u64, u64)]) -> BTreeSet<String> {
    spans.iter().map(|(s, _, _)| s.clone()).collect()
}

/// True once the trace's timeline covers the full stage vocabulary.
fn timeline_complete(handle: &ServerHandle, id: u64) -> bool {
    handle.trace.trace_json(id).is_some_and(|j| {
        let v = parse(&j).unwrap();
        stage_set(&timeline_spans(&v)).len() == ALL_STAGES.len()
    })
}

#[test]
fn traced_two_phase_request_covers_every_pipeline_stage() {
    let dir = synthetic_bundle("obs-stages");
    let handle = serve(ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers: 1,
        host_fallback: true,
        metrics_listen: Some("127.0.0.1:0".into()),
        artifacts_dir: dir.to_str().unwrap().to_string(),
        ..ServerConfig::default()
    })
    .unwrap();
    let id = traced_two_phase(&handle.addr.to_string());
    assert!(
        wait_until(Duration::from_secs(5), || timeline_complete(&handle, id)),
        "timeline never reached all {} stages",
        ALL_STAGES.len()
    );

    let v = parse(&handle.trace.trace_json(id).unwrap()).unwrap();
    assert_eq!(v.req_u64("trace").unwrap(), id);
    let spans = timeline_spans(&v);
    let stages = stage_set(&spans);
    for want in ALL_STAGES {
        assert!(stages.contains(want), "missing stage {want:?} in {stages:?}");
    }
    // monotonic: every span well-formed, the array sorted by start, and
    // the reported total spanning exactly the envelope
    let mut prev_start = 0u64;
    let (mut lo, mut hi) = (u64::MAX, 0u64);
    for (stage, start, end) in &spans {
        assert!(end >= start, "{stage}: end {end} < start {start}");
        assert!(*start >= prev_start, "{stage}: spans not sorted by start");
        prev_start = *start;
        lo = lo.min(*start);
        hi = hi.max(*end);
    }
    assert_eq!(v.req_u64("total_us").unwrap(), hi - lo);

    // queue-wait spans are literally the queue_wait histogram samples:
    // one infer + one activation queued → count 2, sums equal exactly
    let waits: u64 =
        spans.iter().filter(|(s, _, _)| s == "queue_wait").map(|(_, a, b)| b - a).sum();
    let qw = handle.hub.histogram_summary("queue_wait").unwrap();
    assert_eq!(qw.count(), 2, "one infer + one activation were queued");
    assert_eq!(qw.sum_us(), waits, "span durations must equal the histogram samples");

    // the same timeline round-trips over HTTP
    let maddr = handle.metrics_addr.unwrap();
    let (status, body) = http_get(maddr, &format!("/trace?id={id}"));
    assert!(status.contains("200"), "{status}");
    let over_http = parse(&body).unwrap();
    assert_eq!(over_http.req_u64("trace").unwrap(), id);
    assert_eq!(stage_set(&timeline_spans(&over_http)), stages);

    // the index lists the id and no span was dropped on the way
    let (status, body) = http_get(maddr, "/trace");
    assert!(status.contains("200"), "{status}");
    let list = parse(&body).unwrap();
    let listed = list.req_arr("traces").unwrap().iter().any(|t| t.as_i64() == Some(id as i64));
    assert!(listed, "trace index must contain {id}: {body}");
    assert_eq!(list.req_u64("dropped_spans").unwrap(), 0);

    // unknown ids are a JSON 404, not an empty 200
    let (status, body) = http_get(maddr, "/trace?id=999999999");
    assert!(status.contains("404"), "{status}");
    assert!(body.contains("unknown trace"), "{body}");

    handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn reactor_and_threaded_frontends_emit_identical_stage_sets() {
    let dir = synthetic_bundle("obs-parity");
    let mk = |frontend| {
        serve(ServerConfig {
            listen: "127.0.0.1:0".into(),
            workers: 1,
            frontend,
            host_fallback: true,
            artifacts_dir: dir.to_str().unwrap().to_string(),
            ..ServerConfig::default()
        })
        .unwrap()
    };
    let reactor = mk(Frontend::Reactor);
    let threaded = mk(Frontend::Threaded);
    let sets: Vec<BTreeSet<String>> = [&reactor, &threaded]
        .into_iter()
        .map(|h| {
            let id = traced_two_phase(&h.addr.to_string());
            assert!(
                wait_until(Duration::from_secs(5), || timeline_complete(h, id)),
                "incomplete timeline"
            );
            let v = parse(&h.trace.trace_json(id).unwrap()).unwrap();
            stage_set(&timeline_spans(&v))
        })
        .collect();
    // durations differ by design (the threaded read span includes the
    // blocking wait); the observable stage vocabulary must not
    assert_eq!(sets[0], sets[1]);
    reactor.shutdown();
    threaded.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn sampling_disabled_is_inert_and_leaves_replies_untouched() {
    let dir = synthetic_bundle("obs-off");
    let mk = |frontend| {
        // trace_sample stays at its default of 0: tracing fully off
        serve(ServerConfig {
            listen: "127.0.0.1:0".into(),
            workers: 1,
            frontend,
            host_fallback: true,
            artifacts_dir: dir.to_str().unwrap().to_string(),
            ..ServerConfig::default()
        })
        .unwrap()
    };
    let reactor = mk(Frontend::Reactor);
    let threaded = mk(Frontend::Threaded);
    let run = |h: &ServerHandle| {
        let mut conn = BlockingConn::connect(&h.addr.to_string()).unwrap();
        // untraced hello: no id granted, negotiation otherwise unchanged
        let hello = Request::Hello(HelloRequest::default());
        match conn.call(&hello).unwrap() {
            Response::Hello(rep) => assert_eq!(rep.trace, None),
            other => panic!("unexpected {other:?}"),
        }
        let reply = match conn.call(&Request::Infer(paper_request("tinymlp", 0.02))).unwrap() {
            Response::Segment(r) => r,
            other => panic!("unexpected {other:?}"),
        };
        assert_eq!(reply.trace, None, "no trace id leaks into untraced replies");
        let upload = synthetic_upload(&reply, &tiny_arch(), 11);
        let result = match conn.call(&Request::Activation(upload)).unwrap() {
            Response::Result(r) => r,
            other => panic!("unexpected {other:?}"),
        };
        assert_eq!(result.trace, None);
        (reply, result)
    };
    let (ra, res_a) = run(&reactor);
    let (rb, res_b) = run(&threaded);
    // decision, payload, and prediction identical across front-ends
    assert_eq!(ra.pattern, rb.pattern);
    assert_eq!(ra.segment, rb.segment);
    assert_eq!(res_a.prediction, res_b.prediction);
    assert_eq!(res_a.logits, res_b.logits);
    for h in [&reactor, &threaded] {
        h.trace.drain();
        assert_eq!(h.trace.stored(), 0, "sampling off must record nothing");
        assert_eq!(h.trace.spans_dropped(), 0);
    }
    reactor.shutdown();
    threaded.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn slow_exemplar_store_keeps_exactly_n_worst_under_live_traffic() {
    let dir = synthetic_bundle("obs-slow");
    let handle = serve(ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers: 1,
        host_fallback: true,
        metrics_listen: Some("127.0.0.1:0".into()),
        trace_sample: 1.0,
        trace_slow_us: 1, // every real request crosses 1µs
        trace_slow_keep: 2,
        artifacts_dir: dir.to_str().unwrap().to_string(),
        ..ServerConfig::default()
    })
    .unwrap();
    // five accept-sampled requests — nobody negotiated tracing, so the
    // wire stays untouched while spans are recorded server-side
    for i in 0..5 {
        let mut conn = BlockingConn::connect(&handle.addr.to_string()).unwrap();
        match conn.call(&Request::Infer(paper_request("tinymlp", 0.02))).unwrap() {
            Response::Segment(r) => assert_eq!(r.trace, None, "request {i}"),
            other => panic!("unexpected {other:?}"),
        }
    }
    handle.trace.drain();
    assert!(handle.trace.stored() >= 5, "five sampled timelines stored");

    let maddr = handle.metrics_addr.unwrap();
    let (status, body) = http_get(maddr, "/trace/slow");
    assert!(status.contains("200"), "{status}");
    let v = parse(&body).unwrap();
    assert_eq!(v.req_u64("slow_threshold_us").unwrap(), 1);
    let slow = v.req_arr("slow").unwrap();
    assert_eq!(slow.len(), 2, "keeps exactly N worst, not everything seen");
    let totals: Vec<u64> = slow.iter().map(|e| e.req_u64("total_us").unwrap()).collect();
    assert!(totals[0] >= totals[1], "worst first: {totals:?}");
    for e in slow {
        assert!(!e.req_arr("spans").unwrap().is_empty(), "exemplars carry full timelines");
    }
    handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
