"""Model forward-pass structure tests (shapes, partitions, residuals)."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model as M


def _rand_input(spec, n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n, *spec["input_shape"])).astype(np.float32)


@pytest.mark.parametrize("name", list(M.SPECS))
def test_forward_shapes(name):
    spec = M.SPECS[name]()
    params = M.init_params(spec, seed=0)
    x = _rand_input(spec, 2)
    logits = M.forward(spec, params, jnp.asarray(x))
    assert logits.shape == (2, spec["num_classes"])


@pytest.mark.parametrize("name", list(M.SPECS))
def test_split_inference_exact(name):
    """forward == forward(upto=p) ∘ forward_from(p) at every valid p —
    the invariant that makes QPART's partitioning lossless."""
    spec = M.SPECS[name]()
    params = M.init_params(spec, seed=1)
    x = jnp.asarray(_rand_input(spec, 2, seed=1))
    want = np.asarray(M.forward(spec, params, x))
    for p in spec["partition_points"]:
        if p == 0:
            got = np.asarray(M.forward_from(spec, params, x, 0))
        elif p == len(spec["layers"]):
            got = np.asarray(M.forward(spec, params, x, upto=p))
        else:
            h = M.forward(spec, params, x, upto=p)
            got = np.asarray(M.forward_from(spec, params, h, p))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5,
                                   err_msg=f"{name} p={p}")


def test_invalid_partition_asserts():
    spec = M.tinyresnet_spec()
    params = M.init_params(spec, seed=0)
    x = jnp.asarray(_rand_input(spec, 1))
    h = M.forward(spec, params, x, upto=2)  # 2 is inside block 1
    with pytest.raises(AssertionError, match="not allowed"):
        M.forward_from(spec, params, h, 2)


def test_residual_changes_output():
    """tinyresnet's skip adds must actually affect the output."""
    spec = M.tinyresnet_spec()
    params = M.init_params(spec, seed=2)
    x = jnp.asarray(_rand_input(spec, 1, seed=2))
    with_skip = np.asarray(M.forward(spec, params, x))
    spec_noskip = dict(spec, residual={})
    without = np.asarray(M.forward(spec_noskip, params, x))
    assert not np.allclose(with_skip, without)


def test_pallas_path_matches_ref_path():
    spec = M.mlp6_spec()
    params = M.init_params(spec, seed=3)
    x = jnp.asarray(_rand_input(spec, 4, seed=3))
    a = np.asarray(M.forward(spec, params, x, use_pallas=True))
    b = np.asarray(M.forward(spec, params, x, use_pallas=False))
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)


def test_specs_match_rust_zoo_param_counts():
    """Mirror of qpart-core's zoo tests: parameter counts must agree."""
    spec = M.mlp6_spec()
    total = 0
    for layer in spec["layers"]:
        total += layer["d_in"] * layer["d_out"] + layer["d_out"]
    expect = sum(i * o + o for i, o in
                 [(784, 512), (512, 256), (256, 128), (128, 64), (64, 32), (32, 10)])
    assert total == expect

    cnn = M.edgecnn_spec(10)
    conv3 = cnn["layers"][2]
    assert conv3["out_side"] == 8
    assert cnn["layers"][3]["d_in"] == 64 * 8 * 8


def test_quantized_layer_forward():
    spec = M.mlp6_spec()
    params = M.init_params(spec, seed=4)
    layer = spec["layers"][0]
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(2, 784)).astype(np.float32))
    w = np.asarray(params[0]["w"])
    mn, mx = float(w.min()), float(w.max())
    step = (mx - mn) / 255
    codes = np.clip(np.round((w - mn) / step), 0, 255).astype(np.float32)
    out = M.layer_forward_quant(
        layer, jnp.asarray(codes),
        jnp.asarray([[mn]], dtype=jnp.float32), jnp.asarray([[step]], dtype=jnp.float32),
        params[0]["b"][None, :], x)
    ref_out = M.layer_forward(layer, params[0], x)
    # 8-bit weights: outputs close but not identical
    err = float(jnp.max(jnp.abs(out - ref_out)))
    assert 0 < err < 0.5
