//! Quantization: the uniform asymmetric quantizer (paper Eq. 9–10),
//! arbitrary-bit-width bit-packing for the simulated wire (the payload the
//! channel model charges for, Eq. 14), and quantization patterns `(b, p)`
//! (the unit Algorithm 1 produces and Algorithm 2 selects).
//!
//! Hot-path entry points: [`pack_bits`] / [`unpack_bits`] and the fused
//! [`quantize_packed`] (no intermediate code vector), each dispatching
//! once per process between SIMD kernels and the word-wise `*_wordwise`
//! fallbacks (see [`simd`]). The byte-at-a-time `*_scalar` variants are
//! the property-test oracles and the `perf_quant` baselines; the
//! `*_wordwise` variants are the PR 4 kernels the SIMD paths must match
//! byte-for-byte.

mod bitpack;
mod pattern;
mod quantizer;
pub mod simd;

pub use bitpack::{
    pack_bits, pack_bits_scalar, pack_bits_wordwise, packed_len_bytes, unpack_bits,
    unpack_bits_scalar, unpack_bits_wordwise,
};
pub use pattern::{PatternKey, PatternSet, QuantPattern};
pub use quantizer::{
    dequantize, quantize, quantize_packed, quantize_packed_with, quantize_packed_with_wordwise,
    quantize_packed_wordwise, quantize_with, PackedQuantized, QuantParams, Quantized,
};
