//! JSON-lines framing with a hard frame-size cap.

use std::io::{BufRead, Write};

/// Maximum accepted frame size (16 MiB — a full quantized mlp6 segment is
/// well under 1 MiB; the cap only guards against malformed/hostile peers).
pub const MAX_FRAME_BYTES: usize = 16 * 1024 * 1024;

/// Framing errors.
#[derive(Debug)]
pub enum FrameError {
    Io(std::io::Error),
    TooLarge,
    Closed,
    Utf8,
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Io(e) => write!(f, "io: {e}"),
            FrameError::TooLarge => write!(f, "frame exceeds {MAX_FRAME_BYTES} bytes"),
            FrameError::Closed => write!(f, "connection closed"),
            FrameError::Utf8 => write!(f, "frame is not valid utf-8"),
        }
    }
}

impl std::error::Error for FrameError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            FrameError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for FrameError {
    fn from(e: std::io::Error) -> Self {
        FrameError::Io(e)
    }
}

/// Read one newline-terminated frame (without the newline).
pub fn read_frame<R: BufRead>(r: &mut R) -> Result<String, FrameError> {
    let mut buf = Vec::new();
    let mut take = std::io::Read::take(&mut *r, MAX_FRAME_BYTES as u64 + 1);
    let n = take.read_until(b'\n', &mut buf)?;
    if n == 0 {
        return Err(FrameError::Closed);
    }
    if buf.len() > MAX_FRAME_BYTES {
        return Err(FrameError::TooLarge);
    }
    if buf.last() == Some(&b'\n') {
        buf.pop();
    }
    if buf.last() == Some(&b'\r') {
        buf.pop();
    }
    String::from_utf8(buf).map_err(|_| FrameError::Utf8)
}

/// Write one frame + newline and flush.
pub fn write_frame<W: Write>(w: &mut W, frame: &str) -> Result<(), FrameError> {
    debug_assert!(!frame.contains('\n'), "frames must be single-line");
    w.write_all(frame.as_bytes())?;
    w.write_all(b"\n")?;
    w.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufReader;

    #[test]
    fn roundtrip_multiple_frames() {
        let mut buf = Vec::new();
        write_frame(&mut buf, r#"{"a":1}"#).unwrap();
        write_frame(&mut buf, r#"{"b":2}"#).unwrap();
        let mut r = BufReader::new(&buf[..]);
        assert_eq!(read_frame(&mut r).unwrap(), r#"{"a":1}"#);
        assert_eq!(read_frame(&mut r).unwrap(), r#"{"b":2}"#);
        assert!(matches!(read_frame(&mut r), Err(FrameError::Closed)));
    }

    #[test]
    fn crlf_tolerated() {
        let mut r = BufReader::new(&b"hello\r\n"[..]);
        assert_eq!(read_frame(&mut r).unwrap(), "hello");
    }

    #[test]
    fn oversized_rejected() {
        let big = vec![b'x'; MAX_FRAME_BYTES + 10];
        let mut r = BufReader::new(&big[..]);
        assert!(matches!(read_frame(&mut r), Err(FrameError::TooLarge)));
    }

    #[test]
    fn invalid_utf8_rejected() {
        let mut r = BufReader::new(&b"\xff\xfe\n"[..]);
        assert!(matches!(read_frame(&mut r), Err(FrameError::Utf8)));
    }
}
