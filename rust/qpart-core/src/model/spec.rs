//! Layer and model specifications.
//!
//! Conventions (fixed across the whole repo, see DESIGN.md §1):
//! * layers are indexed `1..=L` in paper notation; Rust slices use `0..L`
//!   with `layer l` at index `l-1`;
//! * a partition point `p ∈ 0..=L` means the **device executes layers
//!   `1..=p`** and the server executes `p+1..=L`; `p = 0` sends the raw
//!   (quantized) input straight to the server;
//! * `z_w(l)` counts weight+bias parameters of layer `l`, `z_x(l)` counts
//!   elements of layer `l`'s output activation; `z_x(0)` is the model input.

use crate::error::{Error, Result};
use crate::json::Value;

/// The kinds of learnable layers QPART partitions and quantizes.
#[derive(Debug, Clone, PartialEq)]
pub enum LayerKind {
    /// Fully connected: `X[1,D] · W[D,G] + b[G]` (paper Eq. 1, o = D·G).
    Linear { d_in: usize, d_out: usize },
    /// Standard convolution (paper Eq. 2, o = C_in·C_out·F1·F2·U·V where
    /// U×V is the *output* spatial size under the layer's stride/padding).
    Conv2d {
        c_in: usize,
        c_out: usize,
        k: usize,
        stride: usize,
        /// input spatial side (square feature maps)
        in_side: usize,
        /// output spatial side
        out_side: usize,
    },
}

/// One learnable layer plus its activation bookkeeping.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerSpec {
    /// Human-readable name, e.g. `fc1`, `conv2`.
    pub name: String,
    pub kind: LayerKind,
    /// Whether a ReLU follows (affects runtime execution, not costs).
    pub relu: bool,
}

impl LayerSpec {
    /// Multiply-accumulate operations (paper Eq. 1–2).
    pub fn macs(&self) -> u64 {
        match self.kind {
            LayerKind::Linear { d_in, d_out } => (d_in as u64) * (d_out as u64),
            LayerKind::Conv2d { c_in, c_out, k, out_side, .. } => {
                (c_in as u64) * (c_out as u64) * (k as u64) * (k as u64)
                    * (out_side as u64) * (out_side as u64)
            }
        }
    }

    /// Weight + bias parameter count `z_w`.
    pub fn weight_params(&self) -> u64 {
        match self.kind {
            LayerKind::Linear { d_in, d_out } => (d_in as u64) * (d_out as u64) + d_out as u64,
            LayerKind::Conv2d { c_in, c_out, k, .. } => {
                (c_in as u64) * (c_out as u64) * (k as u64) * (k as u64) + c_out as u64
            }
        }
    }

    /// Output activation element count `z_x`.
    pub fn activation_elems(&self) -> u64 {
        match self.kind {
            LayerKind::Linear { d_out, .. } => d_out as u64,
            LayerKind::Conv2d { c_out, out_side, .. } => {
                (c_out as u64) * (out_side as u64) * (out_side as u64)
            }
        }
    }

    /// Input activation element count.
    pub fn input_elems(&self) -> u64 {
        match self.kind {
            LayerKind::Linear { d_in, .. } => d_in as u64,
            LayerKind::Conv2d { c_in, in_side, .. } => {
                (c_in as u64) * (in_side as u64) * (in_side as u64)
            }
        }
    }
}

/// A full model: ordered learnable layers.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    pub name: String,
    pub layers: Vec<LayerSpec>,
    pub num_classes: usize,
    /// Partition points QPART may choose (`⊆ 0..=L`). Architectures with
    /// residual blocks restrict these to block boundaries so a skip never
    /// crosses the device/server split.
    pub partition_points: Vec<usize>,
    /// Model input shape without the batch dim (e.g. `[784]` or `[3,32,32]`).
    pub input_shape: Vec<usize>,
    /// Residual adds: `(layer, source)` — output of `layer` += output of
    /// `source` (1-based layer indices; `source = 0` is the model input).
    /// No parameters/MACs under Eq. 2, but the runtime must feed the skip.
    pub residual: Vec<(usize, usize)>,
}

impl ModelSpec {
    pub fn new(name: impl Into<String>, layers: Vec<LayerSpec>, num_classes: usize) -> Result<Self> {
        let l = layers.len();
        let input_shape = layers
            .first()
            .map(|layer| match layer.kind {
                LayerKind::Linear { d_in, .. } => vec![d_in],
                LayerKind::Conv2d { c_in, in_side, .. } => vec![c_in, in_side, in_side],
            })
            .unwrap_or_default();
        let spec = ModelSpec {
            name: name.into(),
            layers,
            num_classes,
            partition_points: (0..=l).collect(),
            input_shape,
            residual: Vec::new(),
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Builder: restrict the allowed partition points.
    pub fn with_partitions(mut self, points: Vec<usize>) -> Self {
        self.partition_points = points;
        self
    }

    /// Builder: declare residual adds.
    pub fn with_residual(mut self, residual: Vec<(usize, usize)>) -> Self {
        self.residual = residual;
        self
    }

    /// The residual source feeding `layer`'s output, if any.
    pub fn residual_source(&self, layer: usize) -> Option<usize> {
        self.residual.iter().find(|(l, _)| *l == layer).map(|(_, s)| *s)
    }

    /// Check inter-layer shape consistency.
    pub fn validate(&self) -> Result<()> {
        if self.layers.is_empty() {
            return Err(Error::InvalidArg(format!("model '{}' has no layers", self.name)));
        }
        for w in self.layers.windows(2) {
            let (a, b) = (&w[0], &w[1]);
            if a.activation_elems() != b.input_elems() {
                return Err(Error::Shape(format!(
                    "model '{}': layer '{}' outputs {} elems but layer '{}' expects {}",
                    self.name,
                    a.name,
                    a.activation_elems(),
                    b.name,
                    b.input_elems()
                )));
            }
        }
        Ok(())
    }

    /// Number of learnable layers `L`.
    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// MACs of layer `l ∈ 1..=L` (paper `o(l)`).
    pub fn macs(&self, l: usize) -> u64 {
        self.layers[l - 1].macs()
    }

    /// Device-side MACs for partition `p` (Eq. 3 under our convention):
    /// `O1(p) = Σ_{l=1..p} o(l)`.
    pub fn device_macs(&self, p: usize) -> u64 {
        self.layers[..p].iter().map(LayerSpec::macs).sum()
    }

    /// Server-side MACs for partition `p` (Eq. 4): `O2(p) = Σ_{l=p+1..L} o(l)`.
    pub fn server_macs(&self, p: usize) -> u64 {
        self.layers[p..].iter().map(LayerSpec::macs).sum()
    }

    /// Total MACs.
    pub fn total_macs(&self) -> u64 {
        self.device_macs(self.num_layers())
    }

    /// `z_w(l)`, parameters of layer `l ∈ 1..=L`.
    pub fn weight_params(&self, l: usize) -> u64 {
        self.layers[l - 1].weight_params()
    }

    /// Total parameter count.
    pub fn total_params(&self) -> u64 {
        self.layers.iter().map(LayerSpec::weight_params).sum()
    }

    /// `z_x(l)` for `l ∈ 0..=L`; `z_x(0)` is the model input size.
    pub fn activation_elems(&self, l: usize) -> u64 {
        if l == 0 {
            self.layers[0].input_elems()
        } else {
            self.layers[l - 1].activation_elems()
        }
    }

    /// Full-precision (f32) size of the first segment's weights in bits.
    pub fn segment_weight_bits_f32(&self, p: usize) -> u64 {
        32 * self.layers[..p].iter().map(LayerSpec::weight_params).sum::<u64>()
    }

    /// Communication payload in bits (paper Eq. 14) for partition `p` and
    /// per-layer weight bit-widths `bits[0..p]` plus activation bit-width
    /// `b_x` for the boundary activation `z_x(p)`.
    ///
    /// Downlink: quantized weights of layers `1..=p`. Uplink: quantized
    /// activation of layer `p` (the raw input when `p = 0`).
    pub fn payload_bits(&self, p: usize, bits: &[u8], b_x: u8) -> u64 {
        assert!(bits.len() >= p, "need {} bit-widths, got {}", p, bits.len());
        let w: u64 = (0..p)
            .map(|i| (bits[i] as u64) * self.layers[i].weight_params())
            .sum();
        w + (b_x as u64) * self.activation_elems(p)
    }

    // ----- manifest (de)serialization -----

    pub fn to_json(&self) -> Value {
        Value::obj([
            ("name", self.name.as_str().into()),
            ("num_classes", self.num_classes.into()),
            (
                "partition_points",
                Value::Arr(self.partition_points.iter().map(|&p| p.into()).collect()),
            ),
            (
                "input_shape",
                Value::Arr(self.input_shape.iter().map(|&d| d.into()).collect()),
            ),
            (
                "residual",
                Value::Obj(
                    self.residual
                        .iter()
                        .map(|&(l, s)| (l.to_string(), Value::from(s)))
                        .collect(),
                ),
            ),
            (
                "layers",
                Value::Arr(
                    self.layers
                        .iter()
                        .map(|l| {
                            let mut o = Value::obj([
                                ("name", l.name.as_str().into()),
                                ("relu", l.relu.into()),
                            ]);
                            match l.kind {
                                LayerKind::Linear { d_in, d_out } => {
                                    o.set("kind", "linear".into());
                                    o.set("d_in", d_in.into());
                                    o.set("d_out", d_out.into());
                                }
                                LayerKind::Conv2d { c_in, c_out, k, stride, in_side, out_side } => {
                                    o.set("kind", "conv2d".into());
                                    o.set("c_in", c_in.into());
                                    o.set("c_out", c_out.into());
                                    o.set("k", k.into());
                                    o.set("stride", stride.into());
                                    o.set("in_side", in_side.into());
                                    o.set("out_side", out_side.into());
                                }
                            }
                            o
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(v: &Value) -> Result<ModelSpec> {
        let name = v.req_str("name")?.to_string();
        let num_classes = v.req_usize("num_classes")?;
        let mut layers = Vec::new();
        for lv in v.req_arr("layers")? {
            let lname = lv.req_str("name")?.to_string();
            let relu = lv.opt_bool("relu", false);
            let kind = match lv.req_str("kind")? {
                "linear" => LayerKind::Linear {
                    d_in: lv.req_usize("d_in")?,
                    d_out: lv.req_usize("d_out")?,
                },
                "conv2d" => LayerKind::Conv2d {
                    c_in: lv.req_usize("c_in")?,
                    c_out: lv.req_usize("c_out")?,
                    k: lv.req_usize("k")?,
                    stride: lv.req_usize("stride")?,
                    in_side: lv.req_usize("in_side")?,
                    out_side: lv.req_usize("out_side")?,
                },
                other => {
                    return Err(Error::schema("layers.kind", format!("unknown kind '{other}'")))
                }
            };
            layers.push(LayerSpec { name: lname, kind, relu });
        }
        let mut spec = ModelSpec::new(name, layers, num_classes)?;
        if let Some(pp) = v.get("partition_points").and_then(Value::as_arr) {
            let points = pp
                .iter()
                .map(|x| {
                    x.as_i64()
                        .and_then(|i| usize::try_from(i).ok())
                        .ok_or_else(|| Error::schema("partition_points", "expected indices"))
                })
                .collect::<Result<Vec<_>>>()?;
            for &p in &points {
                if p > spec.layers.len() {
                    return Err(Error::schema("partition_points", format!("point {p} > L")));
                }
            }
            spec.partition_points = points;
        }
        if let Some(shape) = v.get("input_shape").and_then(Value::as_arr) {
            spec.input_shape = shape
                .iter()
                .map(|x| {
                    x.as_i64()
                        .and_then(|i| usize::try_from(i).ok())
                        .ok_or_else(|| Error::schema("input_shape", "expected dims"))
                })
                .collect::<Result<Vec<_>>>()?;
        }
        if let Some(res) = v.get("residual").and_then(Value::as_obj) {
            let mut residual = Vec::new();
            for (k, sv) in res {
                let layer: usize = k
                    .parse()
                    .map_err(|_| Error::schema("residual", "keys must be layer indices"))?;
                let src = sv
                    .as_i64()
                    .and_then(|i| usize::try_from(i).ok())
                    .ok_or_else(|| Error::schema("residual", "expected source index"))?;
                residual.push((layer, src));
            }
            residual.sort_unstable();
            spec.residual = residual;
        }
        Ok(spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lin(name: &str, d_in: usize, d_out: usize) -> LayerSpec {
        LayerSpec { name: name.into(), kind: LayerKind::Linear { d_in, d_out }, relu: true }
    }

    fn toy() -> ModelSpec {
        ModelSpec::new("toy", vec![lin("fc1", 4, 8), lin("fc2", 8, 2)], 2).unwrap()
    }

    #[test]
    fn mac_counts_match_eq1_eq2() {
        let m = toy();
        assert_eq!(m.macs(1), 32);
        assert_eq!(m.macs(2), 16);
        let conv = LayerSpec {
            name: "c".into(),
            kind: LayerKind::Conv2d { c_in: 3, c_out: 8, k: 3, stride: 1, in_side: 8, out_side: 8 },
            relu: true,
        };
        // Eq. 2: C_in × C_out × F1 × F2 × U × V
        assert_eq!(conv.macs(), 3 * 8 * 3 * 3 * 8 * 8);
        assert_eq!(conv.weight_params(), 3 * 8 * 3 * 3 + 8);
        assert_eq!(conv.activation_elems(), 8 * 8 * 8);
    }

    #[test]
    fn segment_costs_partition_sum() {
        let m = toy();
        // Eq. 3/4: O1 + O2 == total at every p
        for p in 0..=m.num_layers() {
            assert_eq!(m.device_macs(p) + m.server_macs(p), m.total_macs());
        }
        assert_eq!(m.device_macs(0), 0);
        assert_eq!(m.server_macs(m.num_layers()), 0);
    }

    #[test]
    fn payload_eq14() {
        let m = toy();
        // p=1, b=[8], b_x=6: 8*(4*8+8) + 6*8
        assert_eq!(m.payload_bits(1, &[8], 6), 8 * 40 + 6 * 8);
        // p=0: raw input quantized at b_x bits
        assert_eq!(m.payload_bits(0, &[], 32), 32 * 4);
    }

    #[test]
    fn shape_mismatch_detected() {
        let bad = ModelSpec::new("bad", vec![lin("a", 4, 8), lin("b", 9, 2)], 2);
        assert!(bad.is_err());
    }

    #[test]
    fn json_roundtrip() {
        let m = toy();
        let v = m.to_json();
        let m2 = ModelSpec::from_json(&v).unwrap();
        assert_eq!(m, m2);
    }

    #[test]
    fn json_roundtrip_conv() {
        let m = ModelSpec::new(
            "c",
            vec![
                LayerSpec {
                    name: "conv1".into(),
                    kind: LayerKind::Conv2d {
                        c_in: 3, c_out: 4, k: 3, stride: 2, in_side: 8, out_side: 4,
                    },
                    relu: true,
                },
                lin("fc", 64, 2),
            ],
            2,
        )
        .unwrap();
        assert_eq!(ModelSpec::from_json(&m.to_json()).unwrap(), m);
    }
}
