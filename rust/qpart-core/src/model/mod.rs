//! Model descriptors: layers, MAC counts (paper Eq. 1–2), segment costs
//! (Eq. 3–4), parameter/activation sizes, and communication payload (Eq. 14).
//!
//! A [`ModelSpec`] is the static description the optimizer works on; the
//! actual weights live in the artifact bundle and are only needed on the
//! serving path. Descriptors therefore also cover models we do not execute
//! (ResNet18/34 for Table IV's payload columns).

mod spec;
mod zoo;

pub use spec::{LayerKind, LayerSpec, ModelSpec};
pub use zoo::{builtin, builtin_names, edgecnn, mlp6, resnet_descriptor, tinyresnet};
