//! The Algorithm-2 decision cache: memoized serving decisions per
//! `(model, accuracy level, bucketed device/channel profile)`.
//!
//! Algorithm 2 is cheap (µs) but runs on **every** request, and a fleet's
//! request stream is dominated by a handful of device classes whose
//! profiles repeat exactly (simulators, SDK defaults, per-class configs).
//! For those, the decision — and the objective value shipped with it — is
//! a pure function of the request's cost-model parameters and the
//! selected accuracy level, so the coordinator memoizes it server-wide:
//! repeat profiles skip planning entirely and the worker goes straight to
//! the encoded-reply cache.
//!
//! **Bucketing.** Continuous profile fields (clocks, channel capacity,
//! tradeoff weights, …) are keyed by a log-scale bucket of ≈0.5% relative
//! width; `memory_bits` is keyed exactly (it gates the
//! feasibility filter). Requests whose profiles land in the same bucket
//! share one decision: for byte-identical profiles (the common case —
//! device classes, not continuous noise) the cached decision is exactly
//! what a fresh `serve_request` would return (tested); profiles that
//! merely *bucket* together get the representative's decision, trading
//! ≤0.5% of parameter fidelity for a planning skip. Callers who cannot
//! accept that trade should bypass the cache.
//!
//! Capacity: FIFO-bounded ([`DecisionCache::with_capacity`]) — the
//! working set is device classes × levels (tens), the bound only guards
//! against adversarial profile churn. Counters surface in the stats
//! document's `decision_cache` section.
//!
//! Only **successful** decisions are memoized, deliberately: infeasible
//! requests are error paths (answered `infeasible` on the wire), a
//! re-plan there costs µs, and never caching failures means a transient
//! mis-profile can't poison the cache for its whole bucket.

use crate::sched::batch::lock_recover;
use crate::store::{keys, CacheCore, Column, EvictPolicy, StoreTier};
use qpart_core::cost::CostModel;
use qpart_core::json::Value;
use qpart_core::optimizer::Decision;
use std::sync::{Arc, Mutex, RwLock, RwLockReadGuard, RwLockWriteGuard};

/// Take the shared lock, recovering from poison: a worker that panicked
/// while holding the lock (supervised + respawned since PR 9) leaves the
/// map structurally intact — every mutation below is a single-step
/// HashMap/VecDeque operation — so serving from it is safe.
pub(crate) fn read_recover<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(|e| e.into_inner())
}

/// Write-lock counterpart of [`read_recover`].
pub(crate) fn write_recover<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(|e| e.into_inner())
}

/// Log-scale bucket of one nonnegative continuous profile field: ≈0.54%
/// relative resolution (2^(1/128) per step). Exact zero, negatives, and
/// non-finite values get their own sentinel buckets so they never alias a
/// real magnitude.
fn qbucket(x: f64) -> i64 {
    if !x.is_finite() {
        return i64::MAX;
    }
    if x == 0.0 {
        return i64::MIN;
    }
    let mag = (x.abs().log2() * 128.0).round() as i64;
    if x < 0.0 {
        // negative magnitudes fold into their own half-range
        i64::MIN / 2 + mag
    } else {
        mag
    }
}

/// The bucketed device/channel/tradeoff profile — the part of a
/// [`DecisionKey`] derived from the request's live parameters.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ProfileBucket {
    device: [i64; 3],
    memory_bits: u64,
    server: [i64; 4],
    channel: [i64; 2],
    weights: [i64; 3],
}

impl ProfileBucket {
    /// Fixed-width little-endian encoding for store keys: 13 × 8 bytes in
    /// declaration order (device, memory_bits, server, channel, weights).
    pub fn to_bytes(&self) -> [u8; 104] {
        let mut out = [0u8; 104];
        let mut at = 0;
        let mut push = |v: i64| {
            out[at..at + 8].copy_from_slice(&v.to_le_bytes());
            at += 8;
        };
        for d in self.device {
            push(d);
        }
        push(self.memory_bits as i64);
        for s in self.server {
            push(s);
        }
        for c in self.channel {
            push(c);
        }
        for w in self.weights {
            push(w);
        }
        out
    }

    /// Inverse of [`ProfileBucket::to_bytes`]; `None` on a wrong-length
    /// slice (a foreign or truncated store key).
    pub fn from_bytes(bytes: &[u8]) -> Option<ProfileBucket> {
        if bytes.len() != 104 {
            return None;
        }
        let mut at = 0;
        let mut next = || {
            let v = i64::from_le_bytes(bytes[at..at + 8].try_into().expect("8-byte chunk"));
            at += 8;
            v
        };
        Some(ProfileBucket {
            device: [next(), next(), next()],
            memory_bits: next() as u64,
            server: [next(), next(), next(), next()],
            channel: [next(), next()],
            weights: [next(), next(), next()],
        })
    }

    /// Bucket every continuous field of `cost` (see the module docs).
    pub fn of(cost: &CostModel) -> ProfileBucket {
        ProfileBucket {
            device: [
                qbucket(cost.device.clock_hz),
                qbucket(cost.device.cycles_per_mac),
                qbucket(cost.device.kappa),
            ],
            memory_bits: cost.device.memory_bits,
            server: [
                qbucket(cost.server.clock_hz),
                qbucket(cost.server.cycles_per_mac),
                qbucket(cost.server.price_per_s),
                qbucket(cost.server.eta_m),
            ],
            channel: [qbucket(cost.channel.capacity_bps), qbucket(cost.channel.tx_power_w)],
            weights: [
                qbucket(cost.weights.omega),
                qbucket(cost.weights.tau),
                qbucket(cost.weights.eta),
            ],
        }
    }
}

/// Cache key: `(model, accuracy-level index, bucketed profile)`. The
/// level index (not the raw budget) is the key's accuracy component —
/// Algorithm 2 consumes the budget only through `select_level`, so two
/// budgets mapping to the same level share a decision by construction.
pub type DecisionKey = (String, usize, ProfileBucket);

/// Server-wide memoization of Algorithm-2 decisions. Shared across all
/// pool workers via `Arc`; one entry per `(model, level, profile bucket)`.
///
/// Since the store tier landed, this type is a typed **facade** over
/// [`CacheCore`] with FIFO eviction (the working set is small and stable;
/// recency tracking would buy nothing — FIFO lookups also stay on the
/// shared lock, so the plan path never serializes the pool on cache
/// hits). When a [`StoreTier`] is attached, every insert stages the
/// bit-exact encoded decision for the segment log and every eviction
/// stages a delete, so a `--warm log` restart replays the live set.
pub struct DecisionCache {
    capacity: usize,
    core: CacheCore<DecisionKey, Arc<Decision>>,
    /// Durable tier, when serving with `--store-dir`.
    store: Mutex<Option<Arc<StoreTier>>>,
}

impl std::fmt::Debug for DecisionCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DecisionCache")
            .field("entries", &self.len())
            .field("capacity", &self.capacity)
            .finish()
    }
}

impl Default for DecisionCache {
    fn default() -> Self {
        DecisionCache::new()
    }
}

impl DecisionCache {
    /// Default capacity: far above any realistic device-class × level
    /// working set, small enough to bound adversarial churn.
    pub fn new() -> DecisionCache {
        DecisionCache::with_capacity(4096)
    }

    pub fn with_capacity(capacity: usize) -> DecisionCache {
        DecisionCache {
            capacity: capacity.max(1),
            core: CacheCore::new(EvictPolicy::FifoCap { capacity: capacity.max(1) }),
            store: Mutex::new(None),
        }
    }

    /// Attach the durable tier: subsequent inserts stage their encoded
    /// decisions for the segment log, evictions stage deletes.
    pub fn attach_store(&self, tier: Arc<StoreTier>) {
        *lock_recover(&self.store) = Some(tier);
    }

    /// Look up a memoized decision, counting the hit/miss. Lookups take
    /// the shared (read) lock: concurrent workers never contend unless
    /// one is inserting.
    pub fn get(&self, key: &DecisionKey) -> Option<Arc<Decision>> {
        self.core.get(key)
    }

    /// Publish a freshly planned decision (idempotent across racing
    /// workers — last write wins, the decisions are equal).
    pub fn insert(&self, key: DecisionKey, decision: Arc<Decision>) {
        self.insert_inner(key, decision, true)
    }

    /// Insert an entry replayed *from* the log (`--warm log`): identical
    /// residency semantics, but the decision is not re-staged.
    pub fn insert_warm(&self, key: DecisionKey, decision: Arc<Decision>) {
        self.insert_inner(key, decision, false)
    }

    fn insert_inner(&self, key: DecisionKey, decision: Arc<Decision>, persist: bool) {
        let store = lock_recover(&self.store).clone();
        let encoded = keys::encode_decision(&decision);
        if persist {
            if let Some(tier) = &store {
                tier.stage_put(Column::Decision, keys::encode_decision_key(&key), encoded.clone());
            }
        }
        let evicted = self.core.insert(key, decision, encoded.len() as u64);
        if let Some(tier) = &store {
            for victim in &evicted {
                tier.stage_delete(Column::Decision, keys::encode_decision_key(victim));
            }
        }
    }

    pub fn hits(&self) -> u64 {
        self.core.hits()
    }

    pub fn misses(&self) -> u64 {
        self.core.misses()
    }

    pub fn evictions(&self) -> u64 {
        self.core.evictions()
    }

    /// Resident entries.
    pub fn len(&self) -> usize {
        self.core.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Hit rate over lookups so far (NaN before the first lookup).
    pub fn hit_rate(&self) -> f64 {
        let h = self.hits() as f64;
        let m = self.misses() as f64;
        h / (h + m)
    }

    /// The unified stats shape (the `caches.decision` section).
    pub fn stats(&self) -> crate::store::CacheStats {
        self.core.stats()
    }

    /// The `decision_cache` section of the stats document (legacy shape,
    /// kept as an alias for one release).
    pub fn to_json(&self) -> Value {
        Value::obj([
            ("entries", self.len().into()),
            ("capacity", self.capacity.into()),
            ("hits", self.hits().into()),
            ("misses", self.misses().into()),
            ("evictions", self.evictions().into()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use qpart_core::accuracy::CalibrationTable;
    use qpart_core::model::mlp6;
    use qpart_core::optimizer::{
        offline_quantize, serve_request_fast, OfflineConfig, RequestParams,
    };

    fn decision() -> Arc<Decision> {
        let m = mlp6();
        let calib = CalibrationTable::synthetic(&m, &[0.01], 3);
        let set = offline_quantize(&m, &calib, OfflineConfig::default()).unwrap();
        let req = RequestParams { cost: CostModel::paper_default(), accuracy_budget: 0.01 };
        Arc::new(serve_request_fast(&m, &set, &req).unwrap())
    }

    fn key(model: &str, cost: &CostModel) -> DecisionKey {
        (model.to_string(), 0, ProfileBucket::of(cost))
    }

    #[test]
    fn identical_profiles_hit_and_share_the_decision() {
        let cache = DecisionCache::new();
        let cost = CostModel::paper_default();
        assert!(cache.get(&key("m", &cost)).is_none());
        assert_eq!(cache.misses(), 1);
        let d = decision();
        cache.insert(key("m", &cost), Arc::clone(&d));
        let got = cache.get(&key("m", &cost)).unwrap();
        assert!(Arc::ptr_eq(&got, &d), "byte-identical profile → shared decision");
        assert_eq!(cache.hits(), 1);
        assert!(cache.hit_rate() > 0.49 && cache.hit_rate() < 0.51);
    }

    #[test]
    fn profile_changes_miss() {
        let cache = DecisionCache::new();
        let base = CostModel::paper_default();
        cache.insert(key("m", &base), decision());
        // a 2× channel is a different bucket, a different level index is a
        // different key, a different model is a different key
        let mut fast = base;
        fast.channel.capacity_bps *= 2.0;
        assert!(cache.get(&key("m", &fast)).is_none());
        assert!(cache.get(&("m".to_string(), 1, ProfileBucket::of(&base))).is_none());
        assert!(cache.get(&key("other", &base)).is_none());
        // memory is exact: one bit of difference misses
        let mut mem = base;
        mem.device.memory_bits = base.device.memory_bits.wrapping_sub(1);
        assert!(cache.get(&key("m", &mem)).is_none());
    }

    #[test]
    fn near_identical_profiles_bucket_together() {
        // 0.1% jitter is inside the ≈0.5% bucket width — the fleet's
        // "same device class, noisy telemetry" case shares the entry
        let base = CostModel::paper_default();
        let mut jitter = base;
        jitter.channel.capacity_bps *= 1.001;
        jitter.device.clock_hz *= 0.9995;
        assert_eq!(ProfileBucket::of(&base), ProfileBucket::of(&jitter));
    }

    #[test]
    fn qbucket_sentinels_do_not_alias() {
        assert_ne!(qbucket(0.0), qbucket(1e-300));
        assert_ne!(qbucket(f64::NAN), qbucket(1e300));
        assert_ne!(qbucket(-1.0), qbucket(1.0));
        assert_eq!(qbucket(1.0), qbucket(1.001));
        assert_ne!(qbucket(1.0), qbucket(1.02));
    }

    #[test]
    fn capacity_evicts_fifo() {
        let cache = DecisionCache::with_capacity(2);
        let d = decision();
        for i in 0..4u64 {
            let mut cost = CostModel::paper_default();
            cost.device.memory_bits = i; // distinct exact keys
            cache.insert(key("m", &cost), Arc::clone(&d));
        }
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.evictions(), 2);
        let mut oldest = CostModel::paper_default();
        oldest.device.memory_bits = 0;
        assert!(cache.get(&key("m", &oldest)).is_none(), "oldest evicted first");
        let mut newest = CostModel::paper_default();
        newest.device.memory_bits = 3;
        assert!(cache.get(&key("m", &newest)).is_some());
    }

    #[test]
    fn attached_store_round_trips_decisions_bit_exact() {
        let dir =
            std::env::temp_dir().join(format!("qpart-dcache-{}-stage", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let tier = StoreTier::open(&dir).unwrap();
        let cache = DecisionCache::with_capacity(1);
        cache.attach_store(Arc::clone(&tier));
        let cost = CostModel::paper_default();
        let d = decision();
        cache.insert(key("m", &cost), Arc::clone(&d));
        tier.flush();
        let persisted = tier
            .get(Column::Decision, &keys::encode_decision_key(&key("m", &cost)))
            .expect("decision persisted");
        let replayed = keys::decode_decision(&persisted).expect("persisted decision decodes");
        assert_eq!(replayed.pattern, d.pattern);
        assert_eq!(replayed.level_idx, d.level_idx);
        assert_eq!(replayed.cost.objective.to_bits(), d.cost.objective.to_bits());
        // capacity-1 cache: the next insert evicts the first, which
        // stages a delete; warm inserts never stage
        cache.insert(key("other", &cost), Arc::clone(&d));
        tier.flush();
        assert!(tier
            .get(Column::Decision, &keys::encode_decision_key(&key("m", &cost)))
            .is_none());
        cache.insert_warm(key("warm", &cost), d);
        assert_eq!(tier.staged_len(), 1, "only the warm insert's eviction is staged");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stats_json_has_all_fields() {
        let cache = DecisionCache::new();
        let v = cache.to_json();
        for k in ["entries", "capacity", "hits", "misses", "evictions"] {
            assert!(v.get(k).is_some(), "{k}");
        }
    }
}
