//! # qpart — facade crate
//!
//! One import for the whole QPART stack (Li et al., CS.DC 2025):
//!
//! ```no_run
//! use qpart::prelude::*;
//!
//! let bundle = std::sync::Arc::new(Bundle::load("artifacts").unwrap());
//! let arch = bundle.arch("mlp6").unwrap();
//! let calib = bundle.calibration("mlp6").unwrap();
//! let patterns = offline_quantize(arch, &calib, OfflineConfig::default()).unwrap();
//! let req = RequestParams {
//!     cost: CostModel::paper_default(),
//!     accuracy_budget: 0.01,
//! };
//! let decision = serve_request(arch, &patterns, &req).unwrap();
//! println!("partition {} bits {:?}", decision.pattern.partition, decision.pattern.weight_bits);
//! ```
//!
//! Layer map (see DESIGN.md):
//! * [`core`] — quantizer, noise/accuracy model, cost/channel models,
//!   closed-form optimizer (Algorithms 1 & 2).
//! * [`runtime`] — PJRT engine + artifact bundle + split-inference executor.
//! * [`sim`] — the paper-§V simulation platform and scheme cost models.
//! * [`coordinator`] — TCP serving stack (service/server/client/metrics)
//!   with the batch-aware serving dataplane (`coordinator::sched`).
//! * [`proto`] — wire protocol (JSON lines + binary segment frames).

pub use qpart_coordinator as coordinator;
pub use qpart_core as core;
pub use qpart_proto as proto;
pub use qpart_runtime as runtime;
pub use qpart_sim as sim;

/// Most-used items in one import.
pub mod prelude {
    pub use qpart_coordinator::{
        serve, DeviceClient, Frontend, Metrics, ServerConfig, Service, WarmMode,
    };
    pub use qpart_core::accuracy::CalibrationTable;
    pub use qpart_core::channel::Channel;
    pub use qpart_core::config::Config;
    pub use qpart_core::cost::{CostModel, DeviceProfile, ServerProfile, TradeoffWeights};
    pub use qpart_core::model::{builtin, ModelSpec};
    pub use qpart_core::optimizer::{
        offline_quantize, serve_request, serve_request_fast, BitBounds, Decision,
        OfflineConfig, RequestParams,
    };
    pub use qpart_core::quant::{PatternSet, QuantPattern};
    pub use qpart_runtime::{Bundle, Executor, HostTensor};
    pub use qpart_sim::{
        run_fleet, scheme_cost, DeviceClass, FleetConfig, Scheme, WorkloadConfig,
    };
}
