//! Serving-dataplane tests — no PJRT required (synthetic bundle).
//!
//! Covers the batch-aware dataplane end to end: coalescing (one encode
//! fans out to a whole same-key group), the encoded-reply cache (hits on
//! re-request, LRU eviction under a tight byte budget), binary-frame
//! negotiation + byte-identical payloads vs. a JSON-frame control, and
//! the session TTL sweep.

use qpart_coordinator::client::paper_request;
use qpart_coordinator::sched::{EncodedReplyCache, Job, WireReply};
use qpart_coordinator::testing::{synthetic_bundle, BlockingConn};
use qpart_coordinator::{serve, MetricsHub, ServerConfig, Service, SharedSessionTable};
use qpart_proto::messages::{HelloRequest, Request, Response};
use qpart_runtime::Bundle;
use std::collections::HashSet;
use std::sync::mpsc::sync_channel;
use std::sync::{Arc, Barrier};
use std::time::Duration;

/// The coalescing contract, deterministically: a batch of same-key infer
/// requests produces exactly one encode, and every reply shares the same
/// encoded body.
#[test]
fn batch_of_same_key_requests_encodes_once_and_fans_out() {
    let dir = synthetic_bundle("dp-batch");
    let bundle = Arc::new(Bundle::load(&dir).unwrap());
    let hub = Arc::new(MetricsHub::new());
    let sessions = Arc::new(SharedSessionTable::new(64, 2));
    let cache = Arc::new(EncodedReplyCache::new(64 << 20));
    let mut svc =
        Service::new(bundle, Arc::clone(&hub), sessions, Arc::clone(&cache)).unwrap();

    let n = 4;
    let mut reply_rxs = Vec::new();
    let mut jobs = Vec::new();
    for _ in 0..n {
        let (tx, rx) = sync_channel(1);
        jobs.push(Job::new(Request::Infer(paper_request("tinymlp", 0.02)), tx));
        reply_rxs.push(rx);
    }
    svc.handle_batch(jobs);

    let mut bodies = Vec::new();
    let mut sessions_seen = HashSet::new();
    for rx in reply_rxs {
        match rx.recv().unwrap().0 {
            WireReply::Segment(s) => {
                assert!(sessions_seen.insert(s.session), "sessions must be distinct");
                bodies.push(s.body);
            }
            other => panic!("unexpected {other:?}"),
        }
    }
    for b in &bodies[1..] {
        assert!(Arc::ptr_eq(&bodies[0], b), "whole group shares ONE encoded body");
    }

    let snap = hub.snapshot();
    assert_eq!(snap.requests_total, n as u64);
    assert_eq!(snap.encodes_total, 1, "one encode for the whole group");
    assert_eq!(snap.coalesced_total, (n - 1) as u64);
    assert_eq!(snap.sessions_opened, n as u64);
    assert_eq!(snap.batches_total, 1);
    assert_eq!(snap.queue_wait_count, n as u64);
    assert_eq!(cache.misses(), 1, "one lookup per group");

    // a later batch for the same key is a pure cache hit — still 1 encode
    let (tx, rx) = sync_channel(1);
    svc.handle_batch(vec![Job::new(Request::Infer(paper_request("tinymlp", 0.02)), tx)]);
    match rx.recv().unwrap().0 {
        WireReply::Segment(s) => {
            assert!(Arc::ptr_eq(&bodies[0], &s.body), "served from cache")
        }
        other => panic!("unexpected {other:?}"),
    }
    assert_eq!(hub.snapshot().encodes_total, 1);
    assert_eq!(cache.hits(), 1);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Server-level coalescing: concurrent same-key requests over TCP produce
/// fewer encodes than requests, and a second pass is >50% cache hits.
#[test]
fn concurrent_same_key_requests_amortize_encodes_over_tcp() {
    let dir = synthetic_bundle("dp-concurrent");
    let handle = serve(ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers: 2,
        queue_capacity: 64,
        session_capacity: 256,
        batch_window: Duration::from_millis(5),
        artifacts_dir: dir.to_str().unwrap().to_string(),
        ..ServerConfig::default()
    })
    .unwrap();
    let addr = handle.addr.to_string();

    let clients = 8usize;
    let run_pass = || {
        let barrier = Arc::new(Barrier::new(clients));
        let joins: Vec<_> = (0..clients)
            .map(|_| {
                let addr = addr.clone();
                let barrier = Arc::clone(&barrier);
                std::thread::spawn(move || {
                    let mut conn = BlockingConn::connect(&addr).unwrap();
                    barrier.wait();
                    match conn.call(&Request::Infer(paper_request("tinymlp", 0.02))).unwrap() {
                        Response::Segment(r) => r.session,
                        other => panic!("unexpected {other:?}"),
                    }
                })
            })
            .collect();
        let mut ids = HashSet::new();
        for j in joins {
            assert!(ids.insert(j.join().unwrap()), "duplicate session");
        }
    };

    run_pass();
    let pass1 = handle.snapshot();
    assert_eq!(pass1.requests_total, clients as u64);
    assert!(pass1.encodes_total >= 1);
    assert!(
        pass1.encodes_total < clients as u64,
        "coalescing/caching must amortize encodes: {} encodes for {clients} requests",
        pass1.encodes_total
    );
    // every request was either the group leader, coalesced, or a hit
    assert!(
        pass1.encodes_total + pass1.coalesced_total + pass1.cache_hits >= clients as u64,
        "{pass1:?}"
    );

    run_pass();
    let pass2 = handle.snapshot();
    assert_eq!(pass2.encodes_total, pass1.encodes_total, "second pass re-encodes nothing");
    assert!(pass2.cache_hits > pass1.cache_hits, "second pass hits the cache");
    // cache hit rate over both passes clears 50%: ≥ the whole second pass
    // minus coalesced requests, over ~1-2 misses total
    let lookups = pass2.cache_hits + pass2.cache_misses;
    assert!(
        (pass2.cache_hits as f64) / (lookups as f64) > 0.5,
        "hit rate {}/{lookups}",
        pass2.cache_hits
    );
    handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Binary-frame negotiation + byte-identical payloads vs. JSON control.
#[test]
fn binary_frames_roundtrip_byte_identical_to_json_control() {
    let dir = synthetic_bundle("dp-binary");
    let handle = serve(ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers: 2,
        artifacts_dir: dir.to_str().unwrap().to_string(),
        ..ServerConfig::default()
    })
    .unwrap();
    let addr = handle.addr.to_string();

    let mut json_conn = BlockingConn::connect(&addr).unwrap();
    let mut bin_conn = BlockingConn::connect(&addr).unwrap();
    let hello = Request::Hello(HelloRequest { binary_frames: true, ..HelloRequest::default() });
    match bin_conn.call(&hello).unwrap() {
        Response::Hello(h) => assert!(h.binary_frames, "server must grant binary frames"),
        other => panic!("unexpected {other:?}"),
    }

    let req = paper_request("tinymlp", 0.02);
    let r_json = match json_conn.call(&Request::Infer(req.clone())).unwrap() {
        Response::Segment(r) => r,
        other => panic!("unexpected {other:?}"),
    };
    let r_bin = match bin_conn.call(&Request::Infer(req.clone())).unwrap() {
        Response::Segment(r) => r,
        other => panic!("unexpected {other:?}"),
    };
    // identical requests → identical pattern and byte-identical payloads;
    // only the session ids differ
    assert_ne!(r_json.session, r_bin.session);
    assert_eq!(r_json.model, r_bin.model);
    assert_eq!(r_json.pattern, r_bin.pattern);
    assert_eq!(r_json.segment, r_bin.segment, "payloads byte-identical across framings");
    for (a, b) in r_json.segment.layers.iter().zip(&r_bin.segment.layers) {
        assert_eq!(a.w_packed, b.w_packed);
        assert_eq!(a.b_packed, b.b_packed);
    }

    // non-segment responses stay JSON even on the binary connection
    assert!(matches!(bin_conn.call(&Request::Ping).unwrap(), Response::Pong));

    // a hello(false) switches the session back to JSON framing
    let hello_off = Request::Hello(HelloRequest::default());
    match bin_conn.call(&hello_off).unwrap() {
        Response::Hello(h) => assert!(!h.binary_frames),
        other => panic!("unexpected {other:?}"),
    }
    match bin_conn.call(&Request::Infer(req)).unwrap() {
        Response::Segment(r) => assert_eq!(r.segment, r_json.segment),
        other => panic!("unexpected {other:?}"),
    }
    handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// A server with binary frames disabled refuses the negotiation.
#[test]
fn binary_frames_can_be_disabled_server_side() {
    let dir = synthetic_bundle("dp-nobinary");
    let handle = serve(ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers: 1,
        binary_frames: false,
        artifacts_dir: dir.to_str().unwrap().to_string(),
        ..ServerConfig::default()
    })
    .unwrap();
    let mut conn = BlockingConn::connect(&handle.addr.to_string()).unwrap();
    let hello = Request::Hello(HelloRequest { binary_frames: true, ..HelloRequest::default() });
    match conn.call(&hello).unwrap() {
        Response::Hello(h) => assert!(!h.binary_frames, "negotiation refused"),
        other => panic!("unexpected {other:?}"),
    }
    // segment replies still arrive (as JSON frames)
    match conn.call(&Request::Infer(paper_request("tinymlp", 0.02))).unwrap() {
        Response::Segment(r) => assert!(r.session > 0),
        other => panic!("unexpected {other:?}"),
    }
    handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Encoded-reply cache eviction under a byte budget too small for two
/// replies: distinct keys displace each other, the resident set stays at
/// one entry, and re-requesting an evicted key re-encodes.
#[test]
fn cache_evicts_under_tight_byte_budget() {
    let dir = synthetic_bundle("dp-evict");
    let handle = serve(ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers: 1,
        cache_bytes: 1, // smaller than any reply: only the newest survives
        artifacts_dir: dir.to_str().unwrap().to_string(),
        ..ServerConfig::default()
    })
    .unwrap();
    let mut conn = BlockingConn::connect(&handle.addr.to_string()).unwrap();

    // distinct accuracy budgets → distinct level_idx → distinct cache keys
    let budgets = [0.01, 0.02, 0.05];
    for &b in &budgets {
        match conn.call(&Request::Infer(paper_request("tinymlp", b))).unwrap() {
            Response::Segment(_) => {}
            other => panic!("budget {b}: unexpected {other:?}"),
        }
    }
    assert_eq!(handle.cache.len(), 1, "budget of 1 byte keeps only the newest entry");
    assert_eq!(handle.cache.evictions(), budgets.len() as u64 - 1);
    assert_eq!(handle.snapshot().encodes_total, budgets.len() as u64);

    // the resident (newest) key hits; an evicted key must re-encode
    let hits_before = handle.cache.hits();
    match conn.call(&Request::Infer(paper_request("tinymlp", 0.05))).unwrap() {
        Response::Segment(_) => {}
        other => panic!("unexpected {other:?}"),
    }
    assert_eq!(handle.cache.hits(), hits_before + 1, "newest entry still resident");
    match conn.call(&Request::Infer(paper_request("tinymlp", 0.01))).unwrap() {
        Response::Segment(_) => {}
        other => panic!("unexpected {other:?}"),
    }
    assert_eq!(
        handle.snapshot().encodes_total,
        budgets.len() as u64 + 1,
        "evicted key re-encodes"
    );
    handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// The session-GC thread makes `sessions_expired` real: sessions whose
/// device never uploads are swept once they outlive the TTL.
#[test]
fn session_ttl_sweep_expires_abandoned_sessions() {
    let dir = synthetic_bundle("dp-ttl");
    let handle = serve(ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers: 1,
        session_ttl: Duration::from_millis(100),
        artifacts_dir: dir.to_str().unwrap().to_string(),
        ..ServerConfig::default()
    })
    .unwrap();
    let mut conn = BlockingConn::connect(&handle.addr.to_string()).unwrap();
    let n = 4u64;
    for _ in 0..n {
        match conn.call(&Request::Infer(paper_request("tinymlp", 0.02))).unwrap() {
            Response::Segment(_) => {}
            other => panic!("unexpected {other:?}"),
        }
    }
    assert_eq!(handle.sessions.len() as u64, n);
    // ttl 100ms, sweep every 25ms: after 600ms everything is expired
    std::thread::sleep(Duration::from_millis(600));
    assert_eq!(handle.sessions.len(), 0, "abandoned sessions swept");
    assert_eq!(handle.sessions.expired(), n);
    assert_eq!(handle.sessions.evicted(), 0, "TTL expiry is not capacity eviction");

    // the stats document reports the sweep
    match conn.call(&Request::Stats).unwrap() {
        Response::Stats(v) => {
            assert_eq!(v.req_f64("sessions_expired").unwrap() as u64, n);
            assert_eq!(v.req_f64("open_sessions").unwrap() as u64, 0);
        }
        other => panic!("unexpected {other:?}"),
    }
    handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
