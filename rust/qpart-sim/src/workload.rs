//! Workload generator: Poisson request arrivals over a heterogeneous
//! device fleet (the edge population of paper §I: phones, watches,
//! cameras, AR glasses — differing clock rates, energy efficiency, memory).

use qpart_core::cost::DeviceProfile;
use qpart_core::rng::Rng;

/// A class of edge devices with a characteristic profile.
#[derive(Debug, Clone)]
pub struct DeviceClass {
    pub name: &'static str,
    pub profile: DeviceProfile,
    /// Relative population weight.
    pub weight: f64,
    /// Accuracy budgets this class requests (sampled uniformly).
    pub accuracy_budgets: Vec<f64>,
}

impl DeviceClass {
    /// A representative heterogeneous fleet (see paper §I motivations).
    pub fn default_fleet() -> Vec<DeviceClass> {
        let base = DeviceProfile::paper_default();
        vec![
            DeviceClass {
                name: "phone",
                profile: DeviceProfile { clock_hz: 2e9, kappa: 1e-27, ..base },
                weight: 0.4,
                accuracy_budgets: vec![0.005, 0.01],
            },
            DeviceClass {
                name: "camera",
                profile: DeviceProfile { clock_hz: 400e6, ..base },
                weight: 0.3,
                accuracy_budgets: vec![0.01, 0.02],
            },
            DeviceClass {
                name: "watch",
                profile: DeviceProfile {
                    clock_hz: 100e6,
                    kappa: 5e-27,
                    memory_bits: 32 * 1024 * 1024 * 8,
                    ..base
                },
                weight: 0.2,
                accuracy_budgets: vec![0.02, 0.05],
            },
            DeviceClass {
                name: "sensor",
                profile: DeviceProfile {
                    clock_hz: 50e6,
                    kappa: 8e-27,
                    memory_bits: 8 * 1024 * 1024 * 8,
                    ..base
                },
                weight: 0.1,
                accuracy_budgets: vec![0.05],
            },
        ]
    }
}

/// Workload configuration.
#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    /// Mean request arrival rate (requests/s, fleet-wide Poisson).
    pub arrival_rate: f64,
    /// Number of devices.
    pub n_devices: usize,
    /// Simulation horizon (s).
    pub duration_s: f64,
    pub seed: u64,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig { arrival_rate: 20.0, n_devices: 16, duration_s: 10.0, seed: 1 }
    }
}

/// One generated request event.
#[derive(Debug, Clone)]
pub struct RequestEvent {
    pub arrival_s: f64,
    pub device: usize,
    pub accuracy_budget: f64,
}

/// Generates the fleet and the arrival sequence.
///
/// Randomness is split into labeled substreams ([`Rng::from_label`]): class
/// assignment, arrival times, and per-request draws each consume their own
/// stream, so changing the fleet composition (or `n_devices`) does not
/// perturb arrival times, and vice versa.
pub struct WorkloadGen {
    pub devices: Vec<(DeviceProfile, &'static str)>,
    pub device_budgets: Vec<Vec<f64>>,
    arrivals: Rng,
    requests: Rng,
    cfg: WorkloadConfig,
}

impl WorkloadGen {
    pub fn new(cfg: WorkloadConfig, classes: &[DeviceClass]) -> WorkloadGen {
        assert!(!classes.is_empty());
        let mut class_rng = Rng::from_label(cfg.seed, "workload/classes");
        let total_w: f64 = classes.iter().map(|c| c.weight).sum();
        let mut devices = Vec::with_capacity(cfg.n_devices);
        let mut device_budgets = Vec::with_capacity(cfg.n_devices);
        for _ in 0..cfg.n_devices {
            let mut pick = class_rng.uniform() * total_w;
            let mut chosen = &classes[0];
            for c in classes {
                if pick < c.weight {
                    chosen = c;
                    break;
                }
                pick -= c.weight;
            }
            devices.push((chosen.profile, chosen.name));
            device_budgets.push(chosen.accuracy_budgets.clone());
        }
        WorkloadGen {
            devices,
            device_budgets,
            arrivals: Rng::from_label(cfg.seed, "workload/arrivals"),
            requests: Rng::from_label(cfg.seed, "workload/requests"),
            cfg,
        }
    }

    /// Generate the full arrival sequence (sorted by time).
    pub fn events(&mut self) -> Vec<RequestEvent> {
        let mut events = Vec::new();
        let mut t = 0.0;
        loop {
            t += self.arrivals.exponential(1.0 / self.cfg.arrival_rate);
            if t >= self.cfg.duration_s {
                break;
            }
            let device = self.requests.range_usize(0, self.devices.len());
            let budgets = &self.device_budgets[device];
            let accuracy_budget = *self.requests.choose(budgets);
            events.push(RequestEvent { arrival_s: t, device, accuracy_budget });
        }
        events
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fleet_respects_population() {
        let cfg = WorkloadConfig { n_devices: 400, seed: 3, ..Default::default() };
        let gen = WorkloadGen::new(cfg, &DeviceClass::default_fleet());
        let phones = gen.devices.iter().filter(|(_, n)| *n == "phone").count();
        // 40% ± sampling noise
        assert!((100..220).contains(&phones), "phones={phones}");
    }

    #[test]
    fn poisson_rate_approximate() {
        let cfg = WorkloadConfig {
            arrival_rate: 50.0,
            duration_s: 20.0,
            n_devices: 4,
            seed: 5,
        };
        let mut gen = WorkloadGen::new(cfg, &DeviceClass::default_fleet());
        let events = gen.events();
        let expected = 50.0 * 20.0;
        assert!(
            (expected * 0.85..expected * 1.15).contains(&(events.len() as f64)),
            "events={}",
            events.len()
        );
        // sorted arrivals
        assert!(events.windows(2).all(|w| w[0].arrival_s <= w[1].arrival_s));
    }

    #[test]
    fn deterministic_by_seed() {
        let cfg = WorkloadConfig::default();
        let a: Vec<f64> = WorkloadGen::new(cfg.clone(), &DeviceClass::default_fleet())
            .events()
            .iter()
            .map(|e| e.arrival_s)
            .collect();
        let b: Vec<f64> = WorkloadGen::new(cfg, &DeviceClass::default_fleet())
            .events()
            .iter()
            .map(|e| e.arrival_s)
            .collect();
        assert_eq!(a, b);
    }

    #[test]
    fn arrivals_survive_fleet_changes() {
        // The labeled-substream split: adding a device class (and growing the
        // population) must not perturb the arrival-time stream.
        let cfg = WorkloadConfig::default();
        let base: Vec<f64> = WorkloadGen::new(cfg.clone(), &DeviceClass::default_fleet())
            .events()
            .iter()
            .map(|e| e.arrival_s)
            .collect();
        let mut classes = DeviceClass::default_fleet();
        classes.push(DeviceClass {
            name: "glasses",
            profile: qpart_core::cost::DeviceProfile::paper_default(),
            weight: 0.15,
            accuracy_budgets: vec![0.01],
        });
        let grown = WorkloadConfig { n_devices: 64, ..cfg };
        let with_extra: Vec<f64> = WorkloadGen::new(grown, &classes)
            .events()
            .iter()
            .map(|e| e.arrival_s)
            .collect();
        assert_eq!(base, with_extra);
    }

    #[test]
    fn default_fleet_first_events_pinned() {
        // Regression pin: the first 16 events of the default fleet. Any
        // change to stream layout or distribution code shows up here.
        let mut gen =
            WorkloadGen::new(WorkloadConfig::default(), &DeviceClass::default_fleet());
        let got: Vec<String> = gen
            .events()
            .iter()
            .take(16)
            .map(|e| format!("{:.4}|{}|{}", e.arrival_s, e.device, e.accuracy_budget))
            .collect();
        let expected = vec![
            "0.1002|8|0.02".to_string(),
            "0.1039|1|0.01".to_string(),
            "0.1245|8|0.02".to_string(),
            "0.1265|11|0.01".to_string(),
            "0.1506|13|0.05".to_string(),
            "0.2035|13|0.05".to_string(),
            "0.2485|13|0.05".to_string(),
            "0.2486|10|0.005".to_string(),
            "0.3044|13|0.05".to_string(),
            "0.3485|13|0.05".to_string(),
            "0.3659|13|0.05".to_string(),
            "0.3897|10|0.01".to_string(),
            "0.3911|2|0.01".to_string(),
            "0.4395|9|0.01".to_string(),
            "0.5040|11|0.01".to_string(),
            "0.5096|8|0.05".to_string(),
        ];
        assert_eq!(got, expected);
    }

    #[test]
    fn budgets_match_class() {
        let cfg = WorkloadConfig { n_devices: 50, seed: 7, ..Default::default() };
        let mut gen = WorkloadGen::new(cfg, &DeviceClass::default_fleet());
        let budgets = gen.device_budgets.clone();
        for e in gen.events() {
            assert!(budgets[e.device].contains(&e.accuracy_budget));
        }
    }
}
