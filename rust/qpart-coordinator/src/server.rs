//! TCP front-end: JSON-lines over TCP, bounded job queue, dedicated
//! inference thread.
//!
//! Topology: N connection threads (one per accepted socket) parse frames
//! and submit `(Request, reply_tx)` jobs into a **bounded** channel — the
//! admission-control point: when the queue is full the request is shed
//! immediately with an `overloaded` error instead of growing latency
//! unboundedly. A single inference thread owns the PJRT executor (the
//! CPU client is one device; serializing there is the honest model) and
//! answers jobs in arrival order.

use crate::metrics::{Metrics, MetricsSnapshot};
use crate::service::Service;
use qpart_proto::frame::{read_frame, write_frame, FrameError};
use qpart_proto::messages::{ErrorReply, Request, Response};
use qpart_runtime::Bundle;
use std::io::BufReader;
use std::net::{TcpListener, TcpStream};
use std::rc::Rc;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Listen address, e.g. `127.0.0.1:7878` (port 0 = ephemeral).
    pub listen: String,
    /// Bounded job-queue depth (admission control).
    pub queue_capacity: usize,
    /// Session-table capacity.
    pub session_capacity: usize,
    /// Artifact bundle directory.
    pub artifacts_dir: String,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            listen: "127.0.0.1:0".into(),
            queue_capacity: 256,
            session_capacity: 4096,
            artifacts_dir: "artifacts".into(),
        }
    }
}

type Job = (Request, SyncSender<Response>);

/// Handle to a running server (for tests/examples).
pub struct ServerHandle {
    pub addr: std::net::SocketAddr,
    pub metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    infer_thread: Option<JoinHandle<()>>,
}

impl ServerHandle {
    /// Signal shutdown and join the threads.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // poke the acceptor so it re-checks the stop flag
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        if let Some(t) = self.infer_thread.take() {
            let _ = t.join();
        }
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }
}

/// Start the server; returns once the listener is bound and the service
/// (bundle + Algorithm 1 tables + PJRT) is initialized.
pub fn serve(cfg: ServerConfig) -> Result<ServerHandle, String> {
    let listener = TcpListener::bind(&cfg.listen).map_err(|e| format!("bind {}: {e}", cfg.listen))?;
    let addr = listener.local_addr().map_err(|e| e.to_string())?;
    let metrics = Arc::new(Metrics::default());
    let stop = Arc::new(AtomicBool::new(false));

    let (job_tx, job_rx): (SyncSender<Job>, Receiver<Job>) = sync_channel(cfg.queue_capacity);

    // Inference thread: owns the (non-Send) service. Bundle + Algorithm 1
    // initialization happens inside; readiness is reported via a channel.
    let (ready_tx, ready_rx) = sync_channel::<Result<(), String>>(1);
    let infer_metrics = Arc::clone(&metrics);
    let infer_stop = Arc::clone(&stop);
    let artifacts_dir = cfg.artifacts_dir.clone();
    let session_capacity = cfg.session_capacity;
    let infer_thread = std::thread::Builder::new()
        .name("qpart-infer".into())
        .spawn(move || {
            let service = Bundle::load(&artifacts_dir)
                .map_err(|e| e.to_string())
                .and_then(|b| {
                    Service::new(Rc::new(b), infer_metrics, session_capacity)
                        .map_err(|e| e.to_string())
                });
            let mut service = match service {
                Ok(s) => {
                    let _ = ready_tx.send(Ok(()));
                    s
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            while !infer_stop.load(Ordering::SeqCst) {
                match job_rx.recv_timeout(std::time::Duration::from_millis(100)) {
                    Ok((req, reply_tx)) => {
                        let resp = service.handle(req);
                        let _ = reply_tx.send(resp);
                    }
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => continue,
                    Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
                }
            }
        })
        .map_err(|e| e.to_string())?;

    match ready_rx.recv() {
        Ok(Ok(())) => {}
        Ok(Err(e)) => return Err(format!("service init failed: {e}")),
        Err(_) => return Err("service thread died during init".into()),
    }

    // Acceptor thread: one connection thread per client.
    let accept_stop = Arc::clone(&stop);
    let accept_metrics = Arc::clone(&metrics);
    let accept_thread = std::thread::Builder::new()
        .name("qpart-accept".into())
        .spawn(move || {
            for stream in listener.incoming() {
                if accept_stop.load(Ordering::SeqCst) {
                    break;
                }
                let stream = match stream {
                    Ok(s) => s,
                    Err(_) => continue,
                };
                // request/response protocol: Nagle + delayed-ACK adds
                // ~40-200 ms per round trip without this
                let _ = stream.set_nodelay(true);
                let job_tx = job_tx.clone();
                let metrics = Arc::clone(&accept_metrics);
                let conn_stop = Arc::clone(&accept_stop);
                let _ = std::thread::Builder::new()
                    .name("qpart-conn".into())
                    .spawn(move || connection_loop(stream, job_tx, metrics, conn_stop));
            }
        })
        .map_err(|e| e.to_string())?;

    Ok(ServerHandle {
        addr,
        metrics,
        stop,
        accept_thread: Some(accept_thread),
        infer_thread: Some(infer_thread),
    })
}

fn connection_loop(
    stream: TcpStream,
    job_tx: SyncSender<Job>,
    metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
) {
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    loop {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let line = match read_frame(&mut reader) {
            Ok(l) => l,
            Err(FrameError::Closed) => break,
            Err(e) => {
                let resp = Response::Error(ErrorReply {
                    code: "bad_frame".into(),
                    message: e.to_string(),
                });
                let _ = write_frame(&mut writer, &resp.to_line());
                break;
            }
        };
        let req = match Request::from_line(&line) {
            Ok(r) => r,
            Err(e) => {
                Metrics::inc(&metrics.errors_total);
                let resp = Response::Error(ErrorReply {
                    code: "bad_request".into(),
                    message: e.to_string(),
                });
                if write_frame(&mut writer, &resp.to_line()).is_err() {
                    break;
                }
                continue;
            }
        };
        let (reply_tx, reply_rx) = sync_channel::<Response>(1);
        let resp = match job_tx.try_send((req, reply_tx)) {
            Ok(()) => match reply_rx.recv() {
                Ok(r) => r,
                Err(_) => Response::Error(ErrorReply {
                    code: "internal".into(),
                    message: "inference thread gone".into(),
                }),
            },
            Err(TrySendError::Full(_)) => {
                Metrics::inc(&metrics.shed_total);
                Response::Error(ErrorReply {
                    code: "overloaded".into(),
                    message: "admission control: job queue full".into(),
                })
            }
            Err(TrySendError::Disconnected(_)) => Response::Error(ErrorReply {
                code: "shutdown".into(),
                message: "server stopping".into(),
            }),
        };
        if write_frame(&mut writer, &resp.to_line()).is_err() {
            break;
        }
    }
}
