//! Framing: JSON lines plus length-prefixed binary frames, with a hard
//! frame-size cap.
//!
//! Two frame kinds share one TCP stream:
//!
//! * **JSON frame** — one UTF-8 JSON document terminated by `'\n'`
//!   ([`read_frame`] / [`write_frame`]). This is the default and the
//!   compatibility fallback; every peer must speak it.
//! * **Binary frame** — a length-prefixed envelope for large payloads
//!   (quantized segment replies downlink, activation uploads uplink),
//!   negotiated per session via the `hello` request. Layout (all
//!   integers little-endian):
//!
//!   ```text
//!   0xB1                        magic byte (invalid as UTF-8 lead byte,
//!                               so it can never open a JSON frame)
//!   u32  total_len              length of everything that follows
//!   u32  header_len             length of the JSON header
//!   header_len bytes            UTF-8 JSON header (small: ids + metadata
//!                               with [offset, length] blob references)
//!   total_len - 4 - header_len  raw blob bytes (bit-packed payloads,
//!                               shipped without base64 or JSON escaping)
//!   ```
//!
//! [`read_any_frame`] peeks one byte to dispatch: `0xB1` → binary,
//! anything else → JSON line. Both kinds enforce [`MAX_FRAME_BYTES`].

use std::io::{BufRead, Read, Write};

/// Maximum accepted frame size (16 MiB — a full quantized mlp6 segment is
/// well under 1 MiB; the cap only guards against malformed/hostile peers).
pub const MAX_FRAME_BYTES: usize = 16 * 1024 * 1024;

/// First byte of a binary frame. `0xB1` is a UTF-8 continuation byte, so
/// it can never start a JSON-lines frame — the two framings are
/// self-distinguishing on the wire.
pub const BINARY_MAGIC: u8 = 0xB1;

/// One frame read off the wire (either framing).
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// A JSON-lines frame (the line, newline stripped).
    Json(String),
    /// A binary frame: JSON header + raw blob.
    Binary(BinaryFrame),
}

/// Payload of a binary frame.
#[derive(Debug, Clone, PartialEq)]
pub struct BinaryFrame {
    /// Small UTF-8 JSON header (ids + metadata with blob offsets).
    pub header: String,
    /// Raw payload bytes the header's offsets point into.
    pub blob: Vec<u8>,
}

/// Framing errors.
#[derive(Debug)]
pub enum FrameError {
    Io(std::io::Error),
    TooLarge,
    Closed,
    Utf8,
    /// Malformed binary frame (bad lengths / truncated envelope).
    BadBinary(String),
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Io(e) => write!(f, "io: {e}"),
            FrameError::TooLarge => write!(f, "frame exceeds {MAX_FRAME_BYTES} bytes"),
            FrameError::Closed => write!(f, "connection closed"),
            FrameError::Utf8 => write!(f, "frame is not valid utf-8"),
            FrameError::BadBinary(m) => write!(f, "bad binary frame: {m}"),
        }
    }
}

impl std::error::Error for FrameError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            FrameError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for FrameError {
    fn from(e: std::io::Error) -> Self {
        FrameError::Io(e)
    }
}

/// Read one newline-terminated frame (without the newline).
pub fn read_frame<R: BufRead>(r: &mut R) -> Result<String, FrameError> {
    let mut buf = Vec::new();
    let mut take = Read::take(&mut *r, MAX_FRAME_BYTES as u64 + 1);
    let n = take.read_until(b'\n', &mut buf)?;
    if n == 0 {
        return Err(FrameError::Closed);
    }
    if buf.len() > MAX_FRAME_BYTES {
        return Err(FrameError::TooLarge);
    }
    if buf.last() == Some(&b'\n') {
        buf.pop();
    }
    if buf.last() == Some(&b'\r') {
        buf.pop();
    }
    String::from_utf8(buf).map_err(|_| FrameError::Utf8)
}

/// Write one frame + newline and flush.
pub fn write_frame<W: Write>(w: &mut W, frame: &str) -> Result<(), FrameError> {
    debug_assert!(!frame.contains('\n'), "frames must be single-line");
    w.write_all(frame.as_bytes())?;
    w.write_all(b"\n")?;
    w.flush()?;
    Ok(())
}

/// Write one binary frame (magic + lengths + header + blob) and flush.
pub fn write_binary_frame<W: Write>(w: &mut W, header: &str, blob: &[u8]) -> Result<(), FrameError> {
    let total = 4 + header.len() + blob.len();
    if total > MAX_FRAME_BYTES {
        return Err(FrameError::TooLarge);
    }
    w.write_all(&[BINARY_MAGIC])?;
    w.write_all(&(total as u32).to_le_bytes())?;
    w.write_all(&(header.len() as u32).to_le_bytes())?;
    w.write_all(header.as_bytes())?;
    w.write_all(blob)?;
    w.flush()?;
    Ok(())
}

/// Try to split one complete frame off the front of `buf` without
/// blocking: `Ok(Some((frame, consumed)))` when a whole frame is
/// buffered, `Ok(None)` when more bytes are needed first.
///
/// This is the incremental twin of [`read_any_frame`] for evented
/// front-ends that accumulate nonblocking reads into a per-connection
/// buffer: the same dispatch (first byte `0xB1` → binary, else JSON
/// line), the same [`MAX_FRAME_BYTES`] cap (a buffer that exceeds it
/// without completing a frame is rejected, so a hostile peer cannot grow
/// the buffer unboundedly), and byte-identical results — only the I/O
/// model differs, never the framing.
pub fn split_frame(buf: &[u8]) -> Result<Option<(Frame, usize)>, FrameError> {
    let Some(&first) = buf.first() else {
        return Ok(None);
    };
    if first != BINARY_MAGIC {
        let Some(pos) = buf.iter().position(|&b| b == b'\n') else {
            // no newline yet: a line longer than the cap never completes
            if buf.len() > MAX_FRAME_BYTES {
                return Err(FrameError::TooLarge);
            }
            return Ok(None);
        };
        if pos + 1 > MAX_FRAME_BYTES {
            return Err(FrameError::TooLarge);
        }
        let mut line = &buf[..pos];
        if line.last() == Some(&b'\r') {
            line = &line[..line.len() - 1];
        }
        let line = std::str::from_utf8(line).map_err(|_| FrameError::Utf8)?;
        return Ok(Some((Frame::Json(line.to_string()), pos + 1)));
    }
    // binary: magic + u32 total, then `total` payload bytes
    if buf.len() < 5 {
        return Ok(None);
    }
    let total = u32::from_le_bytes([buf[1], buf[2], buf[3], buf[4]]) as usize;
    if total > MAX_FRAME_BYTES {
        return Err(FrameError::TooLarge);
    }
    if total < 4 {
        return Err(FrameError::BadBinary(format!("total length {total} < 4")));
    }
    if buf.len() < 5 + total {
        return Ok(None);
    }
    let payload = &buf[5..5 + total];
    let header_len =
        u32::from_le_bytes([payload[0], payload[1], payload[2], payload[3]]) as usize;
    if header_len > total - 4 {
        return Err(FrameError::BadBinary(format!(
            "header length {header_len} exceeds frame payload {}",
            total - 4
        )));
    }
    let header =
        String::from_utf8(payload[4..4 + header_len].to_vec()).map_err(|_| FrameError::Utf8)?;
    let blob = payload[4 + header_len..].to_vec();
    Ok(Some((Frame::Binary(BinaryFrame { header, blob }), 5 + total)))
}

// ---------------------------------------------------------------------------
// Store records — the append-only segment log's on-disk framing
// ---------------------------------------------------------------------------

/// CRC-32 (IEEE 802.3, reflected polynomial `0xEDB88320`) over `data`.
///
/// Guards the store's on-disk records: a record whose body no longer
/// matches its CRC is skipped at replay (counted, never served), while a
/// record whose **envelope** is torn marks the log's recovered tail. No
/// external dependency — the 256-entry table is built at compile time.
pub fn crc32(data: &[u8]) -> u32 {
    const TABLE: [u32; 256] = {
        let mut table = [0u32; 256];
        let mut i = 0;
        while i < 256 {
            let mut c = i as u32;
            let mut k = 0;
            while k < 8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
                k += 1;
            }
            table[i] = c;
            i += 1;
        }
        table
    };
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// Op byte of a store `put` record.
pub const RECORD_PUT: u8 = 1;
/// Op byte of a store `delete` record (a tombstone: the key's earlier
/// puts are dead once this record replays).
pub const RECORD_DELETE: u8 = 2;

/// Fixed bytes of a record after the `total` field: CRC (4) + op (1) +
/// column (1) + key length (4).
const RECORD_OVERHEAD: usize = 10;

/// One decoded store record.
///
/// On-disk layout reuses the binary-frame envelope discipline (magic +
/// little-endian length prefix + [`MAX_FRAME_BYTES`] cap), with a CRC so
/// a half-written or bit-flipped record can never replay as valid state:
///
/// ```text
/// 0xB1                magic byte ([`BINARY_MAGIC`])
/// u32  total          length of everything that follows
/// u32  crc            [`crc32`] of everything after this field
/// u8   op             [`RECORD_PUT`] | [`RECORD_DELETE`]
/// u8   column         store column code (typed-key namespace)
/// u32  key_len        key length
/// key_len bytes       key
/// rest                value (empty for deletes)
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct StoreRecord {
    /// [`RECORD_PUT`] or [`RECORD_DELETE`].
    pub op: u8,
    /// Column code — the typed-key namespace this record belongs to.
    pub column: u8,
    /// Encoded key bytes.
    pub key: Vec<u8>,
    /// Encoded value bytes (empty for deletes).
    pub value: Vec<u8>,
}

/// Result of splitting one record off a replay buffer.
#[derive(Debug, Clone, PartialEq)]
pub enum RecordSplit {
    /// A structurally valid record whose CRC checked out.
    Record(StoreRecord),
    /// The envelope was intact (so the record's extent is known and can
    /// be skipped) but the CRC did not match — corrupted at rest.
    Corrupt,
}

/// Encode one store record (see [`StoreRecord`] for the layout).
pub fn encode_record(
    op: u8,
    column: u8,
    key: &[u8],
    value: &[u8],
) -> Result<Vec<u8>, FrameError> {
    let total = RECORD_OVERHEAD + key.len() + value.len();
    if total > MAX_FRAME_BYTES {
        return Err(FrameError::TooLarge);
    }
    let mut out = Vec::with_capacity(5 + total);
    out.push(BINARY_MAGIC);
    out.extend_from_slice(&(total as u32).to_le_bytes());
    let crc_at = out.len();
    out.extend_from_slice(&[0u8; 4]); // CRC backfilled below
    out.push(op);
    out.push(column);
    out.extend_from_slice(&(key.len() as u32).to_le_bytes());
    out.extend_from_slice(key);
    out.extend_from_slice(value);
    let crc = crc32(&out[crc_at + 4..]);
    out[crc_at..crc_at + 4].copy_from_slice(&crc.to_le_bytes());
    Ok(out)
}

/// Try to split one complete record off the front of `buf`:
/// `Ok(Some((split, consumed)))` when a whole record (valid or corrupt)
/// is buffered, `Ok(None)` when the buffer ends mid-record — at end of
/// file that is the **torn tail**, recovered by truncation. Errors mean
/// the buffer cannot be a record stream at this offset at all (bad magic
/// or a forged length): replay must stop there.
pub fn split_record(buf: &[u8]) -> Result<Option<(RecordSplit, usize)>, FrameError> {
    let Some(&first) = buf.first() else {
        return Ok(None);
    };
    if first != BINARY_MAGIC {
        return Err(FrameError::BadBinary(format!("bad record magic 0x{first:02X}")));
    }
    if buf.len() < 5 {
        return Ok(None);
    }
    let total = u32::from_le_bytes([buf[1], buf[2], buf[3], buf[4]]) as usize;
    if total > MAX_FRAME_BYTES {
        return Err(FrameError::TooLarge);
    }
    if total < RECORD_OVERHEAD {
        return Err(FrameError::BadBinary(format!(
            "record length {total} < {RECORD_OVERHEAD}"
        )));
    }
    if buf.len() < 5 + total {
        return Ok(None);
    }
    let consumed = 5 + total;
    let payload = &buf[5..consumed];
    let crc = u32::from_le_bytes([payload[0], payload[1], payload[2], payload[3]]);
    let body = &payload[4..];
    if crc32(body) != crc {
        return Ok(Some((RecordSplit::Corrupt, consumed)));
    }
    let op = body[0];
    let column = body[1];
    let key_len = u32::from_le_bytes([body[2], body[3], body[4], body[5]]) as usize;
    if op != RECORD_PUT && op != RECORD_DELETE {
        return Err(FrameError::BadBinary(format!("unknown record op {op}")));
    }
    if key_len > body.len() - 6 {
        return Err(FrameError::BadBinary(format!(
            "record key length {key_len} exceeds body {}",
            body.len() - 6
        )));
    }
    let key = body[6..6 + key_len].to_vec();
    let value = body[6 + key_len..].to_vec();
    Ok(Some((RecordSplit::Record(StoreRecord { op, column, key, value }), consumed)))
}

/// Read the next frame of either kind, dispatching on the first byte.
pub fn read_any_frame<R: BufRead>(r: &mut R) -> Result<Frame, FrameError> {
    let first = {
        let buf = r.fill_buf()?;
        if buf.is_empty() {
            return Err(FrameError::Closed);
        }
        buf[0]
    };
    if first != BINARY_MAGIC {
        return Ok(Frame::Json(read_frame(r)?));
    }
    let mut magic = [0u8; 1];
    r.read_exact(&mut magic)?;
    let mut len4 = [0u8; 4];
    r.read_exact(&mut len4)?;
    let total = u32::from_le_bytes(len4) as usize;
    if total > MAX_FRAME_BYTES {
        return Err(FrameError::TooLarge);
    }
    if total < 4 {
        return Err(FrameError::BadBinary(format!("total length {total} < 4")));
    }
    let mut payload = vec![0u8; total];
    r.read_exact(&mut payload)?;
    let header_len =
        u32::from_le_bytes([payload[0], payload[1], payload[2], payload[3]]) as usize;
    if header_len > total - 4 {
        return Err(FrameError::BadBinary(format!(
            "header length {header_len} exceeds frame payload {}",
            total - 4
        )));
    }
    let blob = payload.split_off(4 + header_len);
    let header =
        String::from_utf8(payload[4..].to_vec()).map_err(|_| FrameError::Utf8)?;
    Ok(Frame::Binary(BinaryFrame { header, blob }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufReader;

    #[test]
    fn roundtrip_multiple_frames() {
        let mut buf = Vec::new();
        write_frame(&mut buf, r#"{"a":1}"#).unwrap();
        write_frame(&mut buf, r#"{"b":2}"#).unwrap();
        let mut r = BufReader::new(&buf[..]);
        assert_eq!(read_frame(&mut r).unwrap(), r#"{"a":1}"#);
        assert_eq!(read_frame(&mut r).unwrap(), r#"{"b":2}"#);
        assert!(matches!(read_frame(&mut r), Err(FrameError::Closed)));
    }

    #[test]
    fn crlf_tolerated() {
        let mut r = BufReader::new(&b"hello\r\n"[..]);
        assert_eq!(read_frame(&mut r).unwrap(), "hello");
    }

    #[test]
    fn oversized_rejected() {
        let big = vec![b'x'; MAX_FRAME_BYTES + 10];
        let mut r = BufReader::new(&big[..]);
        assert!(matches!(read_frame(&mut r), Err(FrameError::TooLarge)));
    }

    #[test]
    fn invalid_utf8_rejected() {
        let mut r = BufReader::new(&b"\xff\xfe\n"[..]);
        assert!(matches!(read_frame(&mut r), Err(FrameError::Utf8)));
    }

    #[test]
    fn binary_roundtrip_and_interleaving() {
        let mut buf = Vec::new();
        write_frame(&mut buf, r#"{"a":1}"#).unwrap();
        write_binary_frame(&mut buf, r#"{"kind":"seg"}"#, &[1, 2, 3, 0xB1, 255]).unwrap();
        write_frame(&mut buf, r#"{"b":2}"#).unwrap();
        write_binary_frame(&mut buf, "{}", &[]).unwrap();
        let mut r = BufReader::new(&buf[..]);
        assert_eq!(read_any_frame(&mut r).unwrap(), Frame::Json(r#"{"a":1}"#.into()));
        assert_eq!(
            read_any_frame(&mut r).unwrap(),
            Frame::Binary(BinaryFrame {
                header: r#"{"kind":"seg"}"#.into(),
                blob: vec![1, 2, 3, 0xB1, 255],
            })
        );
        assert_eq!(read_any_frame(&mut r).unwrap(), Frame::Json(r#"{"b":2}"#.into()));
        assert_eq!(
            read_any_frame(&mut r).unwrap(),
            Frame::Binary(BinaryFrame { header: "{}".into(), blob: Vec::new() })
        );
        assert!(matches!(read_any_frame(&mut r), Err(FrameError::Closed)));
    }

    #[test]
    fn binary_oversized_rejected() {
        // a forged length header larger than the cap
        let mut buf = vec![BINARY_MAGIC];
        buf.extend_from_slice(&((MAX_FRAME_BYTES as u32) + 1).to_le_bytes());
        buf.extend_from_slice(&[0u8; 64]);
        let mut r = BufReader::new(&buf[..]);
        assert!(matches!(read_any_frame(&mut r), Err(FrameError::TooLarge)));
        // writing an oversized frame is refused up front
        let blob = vec![0u8; MAX_FRAME_BYTES];
        assert!(matches!(
            write_binary_frame(&mut Vec::new(), "{}", &blob),
            Err(FrameError::TooLarge)
        ));
    }

    #[test]
    fn split_frame_matches_read_any_frame_byte_for_byte() {
        // one buffer holding every frame shape, split incrementally
        let mut buf = Vec::new();
        write_frame(&mut buf, r#"{"a":1}"#).unwrap();
        write_binary_frame(&mut buf, r#"{"kind":"seg"}"#, &[1, 2, 3, 0xB1, 255]).unwrap();
        write_frame(&mut buf, r#"{"b":2}"#).unwrap();
        write_binary_frame(&mut buf, "{}", &[]).unwrap();
        let mut blocking = BufReader::new(&buf[..]);
        let mut rest: &[u8] = &buf;
        for _ in 0..4 {
            let (frame, consumed) = split_frame(rest).unwrap().expect("frame buffered");
            assert_eq!(frame, read_any_frame(&mut blocking).unwrap());
            rest = &rest[consumed..];
        }
        assert!(rest.is_empty());
        assert_eq!(split_frame(rest).unwrap(), None);
    }

    #[test]
    fn split_frame_waits_for_partial_frames() {
        let mut buf = Vec::new();
        write_binary_frame(&mut buf, r#"{"k":1}"#, &[9, 8, 7]).unwrap();
        // every strict prefix is "need more bytes", never an error
        for cut in 0..buf.len() {
            assert_eq!(split_frame(&buf[..cut]).unwrap(), None, "prefix of {cut} bytes");
        }
        let (frame, consumed) = split_frame(&buf).unwrap().unwrap();
        assert_eq!(consumed, buf.len());
        assert!(matches!(frame, Frame::Binary(_)));
        // JSON: no newline yet means incomplete, CRLF stripped when whole
        assert_eq!(split_frame(b"{\"x\":").unwrap(), None);
        let (frame, consumed) = split_frame(b"hello\r\ntrailing").unwrap().unwrap();
        assert_eq!(frame, Frame::Json("hello".into()));
        assert_eq!(consumed, 7);
    }

    #[test]
    fn split_frame_enforces_caps_and_validity() {
        // an endless unterminated line is rejected once past the cap
        let big = vec![b'x'; MAX_FRAME_BYTES + 1];
        assert!(matches!(split_frame(&big), Err(FrameError::TooLarge)));
        // forged binary length beyond the cap
        let mut buf = vec![BINARY_MAGIC];
        buf.extend_from_slice(&((MAX_FRAME_BYTES as u32) + 1).to_le_bytes());
        assert!(matches!(split_frame(&buf), Err(FrameError::TooLarge)));
        // header_len pointing past the payload
        let header = b"{}";
        let mut buf = vec![BINARY_MAGIC];
        buf.extend_from_slice(&((4 + header.len()) as u32).to_le_bytes());
        buf.extend_from_slice(&100u32.to_le_bytes());
        buf.extend_from_slice(header);
        assert!(matches!(split_frame(&buf), Err(FrameError::BadBinary(_))));
        // invalid UTF-8 line
        assert!(matches!(split_frame(b"\xff\xfe\n"), Err(FrameError::Utf8)));
    }

    #[test]
    fn crc32_known_vectors() {
        // the classic IEEE check value plus degenerate inputs
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_ne!(crc32(b"a"), crc32(b"b"));
    }

    #[test]
    fn record_roundtrip_stream() {
        let mut buf = Vec::new();
        buf.extend(encode_record(RECORD_PUT, 1, b"k1", b"v1").unwrap());
        buf.extend(encode_record(RECORD_DELETE, 2, b"k2", b"").unwrap());
        buf.extend(encode_record(RECORD_PUT, 3, b"", b"value-only").unwrap());
        let mut rest: &[u8] = &buf;
        let mut got = Vec::new();
        while let Some((split, n)) = split_record(rest).unwrap() {
            got.push(split);
            rest = &rest[n..];
        }
        assert!(rest.is_empty());
        assert_eq!(
            got,
            vec![
                RecordSplit::Record(StoreRecord {
                    op: RECORD_PUT,
                    column: 1,
                    key: b"k1".to_vec(),
                    value: b"v1".to_vec(),
                }),
                RecordSplit::Record(StoreRecord {
                    op: RECORD_DELETE,
                    column: 2,
                    key: b"k2".to_vec(),
                    value: Vec::new(),
                }),
                RecordSplit::Record(StoreRecord {
                    op: RECORD_PUT,
                    column: 3,
                    key: Vec::new(),
                    value: b"value-only".to_vec(),
                }),
            ]
        );
    }

    #[test]
    fn record_torn_tail_is_incomplete_not_error() {
        let rec = encode_record(RECORD_PUT, 1, b"key", b"value").unwrap();
        // every strict prefix is "need more bytes" — the replayer treats a
        // trailing incomplete record as the torn tail and truncates it
        for cut in 0..rec.len() {
            assert_eq!(split_record(&rec[..cut]).unwrap(), None, "prefix of {cut} bytes");
        }
    }

    #[test]
    fn record_crc_corruption_is_skippable() {
        let mut rec = encode_record(RECORD_PUT, 1, b"key", b"value").unwrap();
        let n = rec.len();
        *rec.last_mut().unwrap() ^= 0xFF; // flip one value byte
        let (split, consumed) = split_record(&rec).unwrap().unwrap();
        assert_eq!(split, RecordSplit::Corrupt);
        assert_eq!(consumed, n, "corrupt record's extent is still known");
        // a valid record after the corrupt one still parses
        rec.extend(encode_record(RECORD_DELETE, 2, b"k", b"").unwrap());
        let (_, n1) = split_record(&rec).unwrap().unwrap();
        let (split, _) = split_record(&rec[n1..]).unwrap().unwrap();
        assert!(matches!(split, RecordSplit::Record(r) if r.op == RECORD_DELETE));
    }

    #[test]
    fn record_envelope_violations_are_errors() {
        // wrong magic: not a record stream at this offset
        assert!(matches!(split_record(b"xyz"), Err(FrameError::BadBinary(_))));
        // forged length beyond the cap
        let mut buf = vec![BINARY_MAGIC];
        buf.extend_from_slice(&((MAX_FRAME_BYTES as u32) + 1).to_le_bytes());
        assert!(matches!(split_record(&buf), Err(FrameError::TooLarge)));
        // length too small to hold the record header
        let mut buf = vec![BINARY_MAGIC];
        buf.extend_from_slice(&3u32.to_le_bytes());
        buf.extend_from_slice(&[0u8; 3]);
        assert!(matches!(split_record(&buf), Err(FrameError::BadBinary(_))));
        // oversized encode refused up front
        let big = vec![0u8; MAX_FRAME_BYTES];
        assert!(matches!(
            encode_record(RECORD_PUT, 1, b"k", &big),
            Err(FrameError::TooLarge)
        ));
    }

    #[test]
    fn binary_bad_lengths_rejected() {
        // header_len pointing past the payload
        let header = b"{}";
        let total = (4 + header.len()) as u32;
        let mut buf = vec![BINARY_MAGIC];
        buf.extend_from_slice(&total.to_le_bytes());
        buf.extend_from_slice(&(100u32).to_le_bytes());
        buf.extend_from_slice(header);
        let mut r = BufReader::new(&buf[..]);
        assert!(matches!(read_any_frame(&mut r), Err(FrameError::BadBinary(_))));
        // total_len too small to hold the header-length field
        let mut buf = vec![BINARY_MAGIC];
        buf.extend_from_slice(&2u32.to_le_bytes());
        buf.extend_from_slice(&[0u8, 0]);
        let mut r = BufReader::new(&buf[..]);
        assert!(matches!(read_any_frame(&mut r), Err(FrameError::BadBinary(_))));
    }
}
