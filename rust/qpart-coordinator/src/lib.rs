//! # qpart-coordinator
//!
//! The Layer-3 serving stack — the QPART server an edge fleet talks to:
//!
//! * [`service`] — the request brain: per-model offline pattern tables
//!   (Algorithm 1 at startup), per-request decisions (Algorithm 2),
//!   segment quantization + bit-packing, session state for the two-phase
//!   protocol, PJRT execution of the server-side segment.
//! * [`server`] — TCP front-end: JSON-lines framing, a bounded job queue
//!   with admission control (overload sheds with an `overloaded` error),
//!   and a configurable **executor pool**: `workers` inference threads,
//!   each owning its own PJRT executor and Algorithm 1 tables (PJRT
//!   clients are single-device and not `Send`), draining one shared
//!   queue. The knob mirrors the simulator's `FleetConfig::server_slots`.
//! * [`client`] — the device side for examples/CLI: sends requests,
//!   executes the received quantized segment locally through its own PJRT
//!   engine, uploads the quantized boundary activation.
//! * [`metrics`] — per-worker counters + histograms, aggregated by a
//!   [`MetricsHub`] and surfaced via the `stats` request.
//! * [`session`] — sharded, capacity-bounded session table shared by all
//!   workers (phase 1 and phase 2 of a session may be handled by
//!   different workers).
//!
//! Python never appears anywhere on these paths.

pub mod client;
pub mod metrics;
pub mod server;
pub mod service;
pub mod session;

pub use client::DeviceClient;
pub use metrics::{Metrics, MetricsHub, MetricsSnapshot};
pub use server::{serve, ServerConfig, ServerHandle};
pub use service::Service;
pub use session::{Session, SessionTable, SharedSessionTable};
