//! # qpart-runtime
//!
//! The Layer-3 ↔ Layer-2 bridge: loads the AOT artifacts produced by
//! `python/compile/aot.py` (HLO text + weights + calibration + datasets)
//! and executes them on the PJRT CPU client via the `xla` crate.
//!
//! * [`engine`] — PJRT client wrapper: compile HLO text files, execute with
//!   f32 literals, executable cache.
//! * [`bundle`] — the artifact bundle: manifest parsing, lazy loading of
//!   weights / calibration tables / datasets.
//! * [`executor`] — split inference: quantize-per-pattern, run the device
//!   segment through the Pallas-kernel executables, quantize the boundary
//!   activation (the simulated uplink), finish on the server segment
//!   (single-row or batched over up to [`executor::EVAL_BATCH`] coalesced
//!   rows, padded to the tightest [`executor::BATCH_LADDER`] rung); plus
//!   full-precision, autoencoder-baseline, and pruning-baseline paths
//!   and batched accuracy evaluation.
//! * [`compile_cache`] — the pool-wide compile cache: compiled
//!   executables, prepared device segments, weight literals, and phase-2
//!   server plans keyed by `(model, partition, fingerprint)`, built once
//!   per server instead of once per pool worker.
//! * [`host`] — pure-Rust reference kernels for f32 linear server
//!   segments, the explicit opt-in phase-2 path when no PJRT backend is
//!   available (tests, `bench-serve`).
//!
//! Python never runs here — the HLO was lowered once at build time; this
//! crate is pure Rust + PJRT and sits on the serving hot path.
//!
//! The `real-xla` cargo feature marks builds against the real `xla`
//! bindings instead of the vendored offline stub (swapped in via the
//! workspace manifest — see the repo README's "Real XLA" section).

pub mod bundle;
pub mod compile_cache;
pub mod engine;
pub mod error;
pub mod executor;
pub mod host;

pub use bundle::{Bundle, DatasetEntry, ExecEntry, ModelEntry, ModelWeights};
pub use compile_cache::{CompileCache, CompileKey, ServerSegmentPlan, WeightLiterals};
pub use engine::{Engine, Exec, HostTensor};
pub use error::{Error, Result};
pub use executor::{
    ladder_fit, Executor, PackedLayer, PackedSegment, PreparedSegment, RowBatchOutcome,
    SplitOutcome, BATCH_LADDER, EVAL_BATCH,
};
