//! Executor-pool integration tests — no PJRT required.
//!
//! These tests drive a real multi-worker server over TCP against the
//! synthetic artifact bundle from `qpart_coordinator::testing` (weights +
//! calibration + dataset, zero HLO executables). The coordinator's
//! phase-1 path — Algorithm 2 decision, segment quantization,
//! bit-packing, session open — is pure Rust, so everything here runs in
//! any offline environment. Only phase-2 execution (PJRT) needs `make
//! artifacts`, and is covered by `rust/qpart/tests/integration.rs`.
//! Dataplane-specific behavior (coalescing, the encoded-reply cache,
//! binary frames, TTL GC) is covered by `tests/dataplane.rs`.

use qpart_coordinator::client::paper_request;
use qpart_coordinator::testing::{synthetic_bundle, BlockingConn};
use qpart_coordinator::{serve, ServerConfig};
use qpart_proto::messages::{ActivationUpload, Request, Response};
use std::collections::HashSet;

#[test]
fn pool_spreads_concurrent_load_over_distinct_workers() {
    let dir = synthetic_bundle("pool-load");
    let handle = serve(ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers: 4,
        queue_capacity: 128,
        session_capacity: 1024,
        artifacts_dir: dir.to_str().unwrap().to_string(),
        ..ServerConfig::default()
    })
    .expect("pool server starts on the synthetic bundle");
    let addr = handle.addr.to_string();

    let clients = 8usize;
    let per_client = 8usize;
    let mut joins = Vec::new();
    for c in 0..clients {
        let addr = addr.clone();
        joins.push(std::thread::spawn(move || {
            let mut conn = BlockingConn::connect(&addr).unwrap();
            let mut sessions = Vec::new();
            for i in 0..per_client {
                let mut req = paper_request("tinymlp", 0.02);
                // distinct live channels → the full Algorithm 2 +
                // quantize + pack path runs under varied decisions
                req.channel_capacity_bps = 1e6 * (1 + c * 7 + i) as f64;
                match conn.call(&Request::Infer(req)).unwrap() {
                    Response::Segment(r) => {
                        assert_eq!(r.pattern.weight_bits.len(), r.pattern.partition);
                        sessions.push(r.session);
                    }
                    other => panic!("client {c} request {i}: unexpected {other:?}"),
                }
            }
            sessions
        }));
    }
    let mut all_sessions = HashSet::new();
    for j in joins {
        for s in j.join().unwrap() {
            assert!(all_sessions.insert(s), "duplicate session id {s}");
        }
    }
    let total = (clients * per_client) as u64;
    assert_eq!(all_sessions.len() as u64, total);

    // per-worker metrics aggregate into ONE logical snapshot...
    let snap = handle.snapshot();
    assert_eq!(snap.requests_total, total);
    assert_eq!(snap.errors_total, 0);
    assert_eq!(snap.sessions_opened, total);
    assert_eq!(snap.handle_count, total);
    // every request's queue wait was recorded
    assert_eq!(snap.queue_wait_count, total);

    // ...and the concurrent load really was serviced by >1 executor
    let per_worker = handle.worker_snapshots();
    assert_eq!(per_worker.len(), 4);
    let counts: Vec<u64> = per_worker.iter().map(|w| w.handle_count).collect();
    assert_eq!(counts.iter().sum::<u64>(), total, "per-worker counts must sum to the total");
    let active = counts.iter().filter(|&&c| c > 0).count();
    assert!(active >= 2, "all requests landed on one worker: {counts:?}");

    // the wire-level stats view is the aggregate, with per-worker detail
    let mut conn = BlockingConn::connect(&addr).unwrap();
    match conn.call(&Request::Stats).unwrap() {
        Response::Stats(v) => {
            // the stats request itself is counted before it reports
            assert_eq!(v.req_f64("requests_total").unwrap() as u64, total + 1);
            assert_eq!(v.req_arr("workers").unwrap().len(), 4);
            assert_eq!(v.req_f64("open_sessions").unwrap() as u64, total);
            assert_eq!(v.req_f64("session_shards").unwrap() as u64, 4);
            // dataplane observability: shard occupancy + cache section
            let occ = v.req_arr("session_shard_occupancy").unwrap();
            assert_eq!(occ.len(), 4);
            let occ_sum: u64 =
                occ.iter().map(|o| o.as_f64().unwrap() as u64).sum();
            assert_eq!(occ_sum, total);
            assert!(v.get("segment_cache").is_some());
            assert!(v.get("queue_wait").is_some());
        }
        other => panic!("unexpected stats response {other:?}"),
    }
    handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn sessions_opened_by_one_worker_are_visible_to_all() {
    let dir = synthetic_bundle("pool-sessions");
    let handle = serve(ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers: 2,
        queue_capacity: 32,
        session_capacity: 64,
        artifacts_dir: dir.to_str().unwrap().to_string(),
        ..ServerConfig::default()
    })
    .unwrap();
    let addr = handle.addr.to_string();

    let mut opener = BlockingConn::connect(&addr).unwrap();
    let mut uploader = BlockingConn::connect(&addr).unwrap();
    for i in 0..8 {
        let reply = match opener.call(&Request::Infer(paper_request("tinymlp", 0.05))).unwrap() {
            Response::Segment(r) => r,
            other => panic!("request {i}: unexpected {other:?}"),
        };
        // Deliberately wrong dims: whichever worker handles phase 2, it
        // must FIND the session (bad_activation), never unknown_session —
        // that is the sharded-table-shared-across-workers contract.
        let upload = ActivationUpload {
            session: reply.session,
            bits: 8,
            qmin: 0.0,
            step: 0.01,
            dims: vec![9, 9],
            packed: vec![0u8; 81],
        };
        match uploader.call(&Request::Activation(upload)).unwrap() {
            Response::Error(e) => {
                assert_eq!(e.code, "bad_activation", "request {i}: {}", e.message)
            }
            other => panic!("request {i}: unexpected {other:?}"),
        }
    }

    // a session id that never existed resolves the same way on any worker
    let upload = ActivationUpload {
        session: 9_999_999,
        bits: 8,
        qmin: 0.0,
        step: 0.01,
        dims: vec![1, 1],
        packed: vec![0u8; 1],
    };
    match uploader.call(&Request::Activation(upload)).unwrap() {
        Response::Error(e) => assert_eq!(e.code, "unknown_session"),
        other => panic!("unexpected {other:?}"),
    }
    handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn single_worker_pool_still_serves() {
    // workers = 1 reproduces the classic dedicated-inference-thread
    // topology; the protocol surface must be identical.
    let dir = synthetic_bundle("pool-single");
    let handle = serve(ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers: 1,
        queue_capacity: 8,
        session_capacity: 16,
        artifacts_dir: dir.to_str().unwrap().to_string(),
        ..ServerConfig::default()
    })
    .unwrap();
    let mut conn = BlockingConn::connect(&handle.addr.to_string()).unwrap();
    assert!(matches!(conn.call(&Request::Ping).unwrap(), Response::Pong));
    match conn.call(&Request::ListModels).unwrap() {
        Response::Models(ms) => {
            assert_eq!(ms.len(), 1);
            assert_eq!(ms[0].name, "tinymlp");
            assert_eq!(ms[0].layers, 3);
        }
        other => panic!("unexpected {other:?}"),
    }
    match conn.call(&Request::Infer(paper_request("tinymlp", 0.02))).unwrap() {
        Response::Segment(r) => assert!(r.session > 0),
        other => panic!("unexpected {other:?}"),
    }
    assert_eq!(handle.worker_snapshots().len(), 1);
    assert_eq!(handle.snapshot().errors_total, 0);
    handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
