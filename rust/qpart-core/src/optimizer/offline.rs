//! Paper **Algorithm 1** — Offline Model Quantization.
//!
//! Enumerate accuracy levels `a ∈ {a_1..a_5}` × partition points
//! `p ∈ 0..=L` and solve the bit-width vector for each, producing the
//! pattern set `{(b_a^p, p)}_θ` the online algorithm searches at request
//! time.
//!
//! The expensive parts of the paper's Algorithm 1 (adversarial-noise
//! estimation, noise-injection thresholds — lines 7–9) happen once in the
//! build-time Python calibration pass; this function consumes the resulting
//! [`CalibrationTable`], so the per-pattern work is just the closed-form
//! solve — microseconds, re-runnable at server startup.

use super::solver::{solve_pattern, BitBounds};
use crate::accuracy::CalibrationTable;
use crate::error::Result;
use crate::model::ModelSpec;
use crate::quant::{PatternSet, QuantPattern};

/// Configuration for the offline pass.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OfflineConfig {
    pub bounds: BitBounds,
    /// If a (level, partition) solve is infeasible at `bounds.max_bits`,
    /// fall back to an un-quantized (32-bit) pattern instead of erroring —
    /// keeps the table total, matching the paper's "no optimization"
    /// degenerate case.
    pub fallback_f32: bool,
}

impl Default for OfflineConfig {
    fn default() -> Self {
        OfflineConfig { bounds: BitBounds::default(), fallback_f32: true }
    }
}

/// Run Algorithm 1: build the full pattern set for `model`.
pub fn offline_quantize(
    model: &ModelSpec,
    calib: &CalibrationTable,
    cfg: OfflineConfig,
) -> Result<PatternSet> {
    calib.validate(model)?;
    let num_levels = calib.levels.len();
    let mut patterns = Vec::with_capacity(num_levels);
    for k in 0..num_levels {
        let mut row = Vec::with_capacity(model.partition_points.len());
        for &p in &model.partition_points {
            match solve_pattern(model, calib, k, p, cfg.bounds) {
                Ok(pat) => row.push(pat),
                Err(crate::Error::Infeasible(_)) if cfg.fallback_f32 => {
                    row.push(QuantPattern {
                        partition: p,
                        weight_bits: vec![32; p],
                        activation_bits: 32,
                        accuracy_level: calib.levels[k],
                        predicted_degradation: 0.0,
                    });
                }
                Err(e) => return Err(e),
            }
        }
        patterns.push(row);
    }
    let mut set = PatternSet {
        model: model.name.clone(),
        levels: calib.levels.clone(),
        patterns,
        segment_bits: Vec::new(),
        payload_bits: Vec::new(),
    };
    // the memory-feasibility and Eq. 14 payload numbers are pure
    // functions of the table — fill them here so Algorithm 2 never
    // re-sums per request
    set.precompute_segment_bits(model);
    set.precompute_payload_bits(model);
    Ok(set)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{edgecnn, mlp6};

    const LEVELS: [f64; 5] = [0.0025, 0.005, 0.01, 0.02, 0.05];

    #[test]
    fn full_table_generated() {
        let m = mlp6();
        let c = CalibrationTable::synthetic(&m, &LEVELS, 21);
        let set = offline_quantize(&m, &c, OfflineConfig::default()).unwrap();
        assert_eq!(set.levels, LEVELS);
        assert_eq!(set.patterns.len(), 5);
        for row in &set.patterns {
            assert_eq!(row.len(), m.num_layers() + 1);
            for (p, pat) in row.iter().enumerate() {
                assert_eq!(pat.partition, p);
                pat.validate(&m).unwrap();
            }
        }
    }

    #[test]
    fn restricted_partitions_respected() {
        let m = crate::model::tinyresnet(10);
        let c = CalibrationTable::synthetic(&m, &LEVELS, 27);
        let set = offline_quantize(&m, &c, OfflineConfig::default()).unwrap();
        for row in &set.patterns {
            let ps: Vec<usize> = row.iter().map(|p| p.partition).collect();
            assert_eq!(ps, m.partition_points, "only block-boundary partitions");
        }
    }

    #[test]
    fn degradation_within_level_everywhere() {
        let m = mlp6();
        let c = CalibrationTable::synthetic(&m, &LEVELS, 22);
        let set = offline_quantize(&m, &c, OfflineConfig::default()).unwrap();
        for (k, row) in set.patterns.iter().enumerate() {
            for pat in row {
                assert!(
                    pat.predicted_degradation <= LEVELS[k] * (1.0 + 1e-9),
                    "k={k} p={}: {}",
                    pat.partition,
                    pat.predicted_degradation
                );
            }
        }
    }

    #[test]
    fn payload_shrinks_with_tolerance_per_partition() {
        // Fig. 6 shape holds at every partition point of the table.
        let m = mlp6();
        let c = CalibrationTable::synthetic(&m, &LEVELS, 23);
        let set = offline_quantize(&m, &c, OfflineConfig::default()).unwrap();
        for p in 0..=m.num_layers() {
            for k in 1..LEVELS.len() {
                let tight = set.patterns[k - 1][p].payload_bits(&m);
                let loose = set.patterns[k][p].payload_bits(&m);
                assert!(loose <= tight, "p={p} k={k}: {loose} > {tight}");
            }
        }
    }

    #[test]
    fn works_for_conv_models() {
        let m = edgecnn(10);
        let c = CalibrationTable::synthetic(&m, &LEVELS, 24);
        let set = offline_quantize(&m, &c, OfflineConfig::default()).unwrap();
        assert_eq!(set.patterns[0].len(), m.num_layers() + 1);
    }

    #[test]
    fn mismatched_calibration_rejected() {
        let m = mlp6();
        let other = edgecnn(10);
        let c = CalibrationTable::synthetic(&other, &LEVELS, 25);
        assert!(offline_quantize(&m, &c, OfflineConfig::default()).is_err());
    }

    #[test]
    fn infeasible_falls_back_to_f32() {
        let m = mlp6();
        let mut c = CalibrationTable::synthetic(&m, &LEVELS, 26);
        // make layer 1 absurdly touchy at the tightest level
        c.weight[0].s = 1e30;
        let set = offline_quantize(&m, &c, OfflineConfig::default()).unwrap();
        let pat = &set.patterns[0][m.num_layers()];
        assert_eq!(pat.weight_bits, vec![32; m.num_layers()]);

        let strict = OfflineConfig { fallback_f32: false, ..Default::default() };
        assert!(offline_quantize(&m, &c, strict).is_err());
    }
}
