//! Minimal CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, repeated
//! `--set k=v`, and positional arguments.

use std::collections::HashMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: HashMap<String, Vec<String>>,
}

impl Args {
    /// Parse from an iterator of raw args (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args, String> {
        let mut args = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if rest.is_empty() {
                    // `--` ends flag parsing
                    args.positional.extend(iter);
                    break;
                }
                let (key, val) = match rest.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                let val = match val {
                    Some(v) => v,
                    None => {
                        // consume the next token unless it is another flag
                        match iter.peek() {
                            Some(n) if !n.starts_with("--") => iter.next().unwrap(),
                            _ => "true".to_string(),
                        }
                    }
                };
                args.flags.entry(key).or_default().push(val);
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).and_then(|v| v.last()).map(String::as_str)
    }

    pub fn get_all(&self, key: &str) -> Vec<&str> {
        self.flags.get(key).map(|v| v.iter().map(String::as_str).collect()).unwrap_or_default()
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|_| format!("--{key}: expected a number, got '{s}'")),
        }
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|_| format!("--{key}: expected an integer, got '{s}'")),
        }
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn flags_and_positionals() {
        let a = parse(&["serve", "--listen", "0.0.0.0:9", "--verbose", "--k=v"]);
        assert_eq!(a.positional, vec!["serve"]);
        assert_eq!(a.get("listen"), Some("0.0.0.0:9"));
        assert_eq!(a.get("k"), Some("v"));
        assert!(a.has("verbose"));
        assert_eq!(a.get("verbose"), Some("true"));
    }

    #[test]
    fn repeated_and_numbers() {
        let a = parse(&["--set", "a=1", "--set", "b=2", "--rate", "2.5", "--n", "7"]);
        assert_eq!(a.get_all("set"), vec!["a=1", "b=2"]);
        assert_eq!(a.get_f64("rate", 0.0).unwrap(), 2.5);
        assert_eq!(a.get_usize("n", 0).unwrap(), 7);
        assert_eq!(a.get_usize("missing", 3).unwrap(), 3);
        assert!(a.get_f64("set", 0.0).is_err());
    }

    #[test]
    fn double_dash_ends_flags() {
        let a = parse(&["--x", "1", "--", "--not-a-flag"]);
        assert_eq!(a.positional, vec!["--not-a-flag"]);
    }
}
