//! Quickstart: the whole QPART decision + serving pipeline in one file.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! 1. Load the artifact bundle (weights + calibration + HLO executables).
//! 2. Run paper **Algorithm 1** (offline): build the pattern table.
//! 3. Run paper **Algorithm 2** (online) for one edge request.
//! 4. Execute the decided split inference on PJRT: quantized device
//!    segment (Pallas-kernel executables) → simulated uplink → f32 server
//!    segment; compare against full-precision inference.

use qpart::prelude::*;
use std::sync::Arc;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let Ok(bundle) = Bundle::load("artifacts") else {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        return Ok(());
    };
    let bundle = Arc::new(bundle);
    let arch = bundle.arch("mlp6")?.clone();
    println!(
        "model mlp6: {} layers, {} params, input {:?}",
        arch.num_layers(),
        arch.total_params(),
        arch.input_shape
    );

    // ---- Algorithm 1 (offline): calibration → pattern table
    let calib = bundle.calibration("mlp6")?;
    let t0 = std::time::Instant::now();
    let patterns = offline_quantize(&arch, &calib, OfflineConfig::default())?;
    println!(
        "Algorithm 1: {} levels × {} partitions solved in {:?}",
        patterns.levels.len(),
        patterns.num_partitions(),
        t0.elapsed()
    );

    // ---- Algorithm 2 (online): one request (paper Table II device)
    let request = RequestParams {
        cost: CostModel::paper_default(),
        accuracy_budget: 0.01, // ≤1% degradation please
    };
    let t0 = std::time::Instant::now();
    let decision = serve_request(&arch, &patterns, &request)?;
    println!(
        "Algorithm 2 ({:?}): partition p={}, weight bits {:?}, activation bits {}, \
         predicted degradation {:.3}%",
        t0.elapsed(),
        decision.pattern.partition,
        decision.pattern.weight_bits,
        decision.pattern.activation_bits,
        decision.pattern.predicted_degradation * 100.0
    );
    println!(
        "  objective {:.5}  (time {:.2} ms, device energy {:.3} mJ, server cost {:.2e})",
        decision.cost.objective,
        decision.cost.total_time_s() * 1e3,
        decision.cost.total_energy_j() * 1e3,
        decision.cost.server_cost
    );
    println!(
        "  payload {} bits vs f32 {} bits → {:.1}% reduction",
        decision.pattern.payload_bits(&arch),
        decision.pattern.payload_bits_f32(&arch),
        100.0
            * (1.0
                - decision.pattern.payload_bits(&arch) as f64
                    / decision.pattern.payload_bits_f32(&arch) as f64)
    );

    // ---- execute the split on PJRT
    let mut ex = Executor::new(Arc::clone(&bundle))?;
    let (x, y) = bundle.dataset("digits")?;
    let x = HostTensor::from(x);
    let input = x.slice_rows_padded(0, 1, 1);
    let outcome = ex.run_split("mlp6", &decision.pattern, input.clone())?;
    let full = ex.run_full_reference(&arch, input)?;
    let argmax = |v: &[f32]| {
        v.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0 as i32
    };
    println!(
        "\nsplit inference: prediction {} (full-precision {}, label {})",
        argmax(&outcome.logits.data),
        argmax(&full.data),
        y[0]
    );
    println!(
        "wire: {} weight bits down, {} activation bits up",
        outcome.weight_bits, outcome.activation_bits
    );
    Ok(())
}

/// Small helper so the example stays one file.
trait FullRef {
    fn run_full_reference(
        &mut self,
        arch: &ModelSpec,
        x: HostTensor,
    ) -> qpart::runtime::Result<HostTensor>;
}
impl FullRef for Executor {
    fn run_full_reference(
        &mut self,
        arch: &ModelSpec,
        x: HostTensor,
    ) -> qpart::runtime::Result<HostTensor> {
        let w = self.weights("mlp6")?;
        self.run_server_segment(arch, &w, x, 0)
    }
}
