//! The connection reactor: one thread, `poll(2)` readiness, every
//! accepted socket a [`Conn`] state machine in a slab.
//!
//! This replaces the thread-per-connection accept loop: accepted-device
//! count is no longer capped by OS threads — one reactor thread carries
//! thousands of connections while the executor pool stays exactly as
//! wide as `--workers`. The division of labor:
//!
//! * **reactor thread** — accepts (gated by `max_conns`), reads
//!   nonblocking sockets into per-connection buffers, splits frames,
//!   answers connection-level traffic itself (`hello` negotiation,
//!   framing errors, shed replies), and submits everything else to the
//!   shared job queue as [`Job::routed`] jobs tagged with the
//!   connection's token.
//! * **executor pool** — unchanged: drains the queue in batches,
//!   coalesces, executes, and replies through the [`ReplyRouter`]
//!   completion queue instead of a per-thread channel. A push wakes the
//!   reactor ([`Waker`]), which serializes the reply in the connection's
//!   negotiated framing into its outbox and flushes as writability
//!   allows.
//!
//! Tokens are `(slot index, generation)` pairs: a connection that dies
//! while its job is in flight bumps the slot generation, so the late
//! reply routes to nobody instead of to whoever reused the slot.
//!
//! Timeouts: a connection with nothing in flight and no byte moved for
//! `idle_timeout` is closed (`conns_timed_out`) — this is what defuses
//! slow-loris / half-open peers, which previously pinned a thread each.
//! Backpressure: replies queue in the connection's outbox; a connection
//! whose outbox is deep (or with a request in flight) is not polled for
//! reads, so TCP pushes back on the peer instead of the server buffering
//! unboundedly.
//!
//! A second listener socket (`--metrics-listen`) rides the same reactor
//! as a trivial second [`ConnKind`]: accepted scrape connections wait
//! for their HTTP request line, get the path-routed response queued
//! (`/metrics` scrape, `/trace` endpoints), and close once flushed.

use crate::metrics::{request_path, Metrics, MetricsHub};
use crate::net::conn::{Conn, ConnKind, Outbox};
use crate::net::sys::{poll_fds, PollFd, Waker, POLLIN, POLLOUT};
use crate::obs::{JobTrace, Stage, TraceSink, TraceStamp, Tracer, TrafficRecorder, FRONT_WORKER};
use crate::sched::{FairQueue, Job, ReplyRouter, WireReply};
use crate::session::SharedSessionTable;
use qpart_proto::frame::{write_binary_frame, write_frame, Frame};
use qpart_proto::messages::{ErrorReply, HelloReply, Request, Response, JSON_FRAME_TAIL};
use std::io::{self, Write};
use std::net::TcpListener;
use std::os::unix::io::{AsRawFd, RawFd};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{SyncSender, TrySendError};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Poll tick: the upper bound on how late the reactor notices a stop
/// request or an idle deadline when no fd event arrives first (replies
/// and traffic wake it immediately).
const TICK_MS: i32 = 25;

/// Outbox depth beyond which a connection stops being polled for reads
/// (resumes once the peer drains below it).
const OUTBOX_PAUSE_BYTES: usize = 1 << 20;

/// Concurrent metrics-scrape connections allowed. Scrapes are transient
/// and have their own small bound so they neither consume the protocol
/// `max_conns` budget nor let slow scrapers grow without limit.
const METRICS_CONN_CAP: usize = 64;

/// Idle bound for metrics-scrape connections (independent of
/// `--conn-idle-secs`, which is sized for silently-computing devices):
/// a scraper that never sends its request or never drains the response
/// is reaped on this much shorter clock.
const SCRAPE_IDLE: Duration = Duration::from_secs(10);

/// Everything a [`Reactor`] needs from the server assembly.
pub struct ReactorParams {
    /// The protocol listener (the reactor makes it nonblocking).
    pub listener: TcpListener,
    /// Optional metrics-scrape listener riding the same poll loop.
    pub metrics_listener: Option<TcpListener>,
    /// Accept gate: protocol connections beyond this are refused with a
    /// `max_conns` error line (`conns_rejected_total`).
    pub max_conns: usize,
    /// Close connections with nothing in flight and no bytes moved for
    /// this long (zero disables; `conns_timed_out`).
    pub idle_timeout: Duration,
    /// Whether `hello` may grant binary framing.
    pub binary_allowed: bool,
    /// The executor pool's job queue.
    pub job_tx: SyncSender<Job>,
    /// Metrics hub (front-end counters + the scrape document).
    pub hub: Arc<MetricsHub>,
    /// Session table (scrape document's `open_sessions`).
    pub sessions: Arc<SharedSessionTable>,
    /// Per-connection fair-queue token buckets (inert when disabled).
    pub fair: Arc<FairQueue>,
    /// Trace sink: accept sampling, hello-negotiated grants, and the
    /// front-end spans (read/admit/route/flush). Always present — with
    /// sampling off and no grants, no span is ever emitted and the
    /// per-request cost is one `Option` check.
    pub trace: Arc<TraceSink>,
    /// Optional live-traffic recorder (`--record-trace`).
    pub recorder: Option<Arc<TrafficRecorder>>,
    /// Cooperative shutdown flag, checked every tick.
    pub stop: Arc<AtomicBool>,
    /// Graceful-drain flag: while set, new protocol connections are
    /// refused with a `draining` error and existing connections are
    /// closed as soon as they go quiescent (nothing in flight, outbox
    /// flushed, no buffered request bytes). In-flight work completes.
    pub drain: Arc<AtomicBool>,
}

struct Slot {
    gen: u32,
    conn: Option<Conn>,
}

/// The poll-based front-end. Construct with [`Reactor::new`], then call
/// [`Reactor::run`] on a dedicated thread.
pub struct Reactor {
    listener: TcpListener,
    metrics_listener: Option<TcpListener>,
    max_conns: usize,
    idle_timeout: Duration,
    binary_allowed: bool,
    job_tx: SyncSender<Job>,
    router: Arc<ReplyRouter>,
    waker: Arc<Waker>,
    front: Arc<Metrics>,
    hub: Arc<MetricsHub>,
    sessions: Arc<SharedSessionTable>,
    fair: Arc<FairQueue>,
    /// The front-end's span emitter (worker id [`FRONT_WORKER`]).
    tracer: Tracer,
    recorder: Option<Arc<TrafficRecorder>>,
    stop: Arc<AtomicBool>,
    drain: Arc<AtomicBool>,
    slots: Vec<Slot>,
    free: Vec<usize>,
    /// Live protocol connections (the `max_conns` gate's denominator —
    /// scrape connections have their own bound and don't count here).
    proto_open: usize,
    /// Live metrics-scrape connections (bounded by [`METRICS_CONN_CAP`]).
    metrics_open: usize,
}

impl Reactor {
    pub fn new(params: ReactorParams) -> io::Result<Reactor> {
        let waker = Arc::new(Waker::new()?);
        let wake = Arc::clone(&waker);
        let router = Arc::new(ReplyRouter::new(Box::new(move || wake.wake())));
        let front = params.hub.front();
        Ok(Reactor {
            listener: params.listener,
            metrics_listener: params.metrics_listener,
            max_conns: params.max_conns.max(1),
            idle_timeout: params.idle_timeout,
            binary_allowed: params.binary_allowed,
            job_tx: params.job_tx,
            router,
            waker,
            front,
            hub: params.hub,
            sessions: params.sessions,
            fair: params.fair,
            tracer: params.trace.tracer(FRONT_WORKER),
            recorder: params.recorder,
            stop: params.stop,
            drain: params.drain,
            slots: Vec::new(),
            free: Vec::new(),
            proto_open: 0,
            metrics_open: 0,
        })
    }

    /// The event loop. Returns when the stop flag is set; every
    /// connection is dropped (workers drain what is already queued and
    /// their late replies route to nobody).
    pub fn run(mut self) {
        if self.listener.set_nonblocking(true).is_err() {
            return;
        }
        if let Some(l) = &self.metrics_listener {
            if l.set_nonblocking(true).is_err() {
                self.metrics_listener = None;
            }
        }
        let mut fds: Vec<PollFd> = Vec::new();
        let mut conn_fds: Vec<(usize, u32, RawFd)> = Vec::new();
        while !self.stop.load(Ordering::SeqCst) {
            // interest set: waker, listeners, then one entry per live conn
            fds.clear();
            conn_fds.clear();
            fds.push(PollFd::new(self.waker.fd(), POLLIN));
            fds.push(PollFd::new(self.listener.as_raw_fd(), POLLIN));
            if let Some(l) = &self.metrics_listener {
                fds.push(PollFd::new(l.as_raw_fd(), POLLIN));
            }
            let base = fds.len();
            let mut outbox_bytes = 0u64;
            for (slot, s) in self.slots.iter().enumerate() {
                if let Some(c) = &s.conn {
                    outbox_bytes += c.outbox.bytes() as u64;
                    let mut events = 0i16;
                    if c.wants_read(OUTBOX_PAUSE_BYTES) {
                        events |= POLLIN;
                    }
                    if c.wants_write() {
                        events |= POLLOUT;
                    }
                    fds.push(PollFd::new(c.stream.as_raw_fd(), events));
                    conn_fds.push((slot, s.gen, c.stream.as_raw_fd()));
                }
            }
            Metrics::set(&self.front.outbox_bytes, outbox_bytes);
            Metrics::observe_peak(&self.front.outbox_bytes_peak, outbox_bytes);
            if poll_fds(&mut fds, TICK_MS).is_err() {
                // should be unreachable (we own every fd); don't spin
                std::thread::sleep(Duration::from_millis(5));
                continue;
            }
            // completions first: routed replies free connections to read
            // their next pipelined request in this same tick
            self.waker.drain();
            for (token, reply, stamp) in self.router.drain() {
                self.route(token, reply, stamp);
            }
            if fds[1].ready() {
                self.accept_proto();
            }
            if self.metrics_listener.is_some() && fds[2].ready() {
                self.accept_metrics();
            }
            for (&(slot, gen, fd), pfd) in conn_fds.iter().zip(&fds[base..]) {
                if !pfd.ready() {
                    continue;
                }
                // The slot may have been closed — and even reused by an
                // accept — while routing completions above, and the
                // kernel hands a fresh socket the lowest free fd number,
                // so the fd alone can collide with the dead conn's.
                // The generation (bumped on every release) is the
                // authoritative identity; stale readiness is dropped.
                let live = match self.slots.get(slot) {
                    Some(s) => {
                        s.gen == gen
                            && s.conn.as_ref().map(|c| c.stream.as_raw_fd()) == Some(fd)
                    }
                    None => false,
                };
                if !live {
                    continue;
                }
                if pfd.broken() {
                    if let Some(conn) = self.slots[slot].conn.take() {
                        self.release(slot, conn, false);
                    }
                    continue;
                }
                self.drive(slot, pfd.readable());
            }
            self.sweep_idle();
            if self.drain.load(Ordering::SeqCst) {
                self.sweep_drained();
            }
        }
    }

    /// Drain mode: close protocol connections that have gone quiescent —
    /// nothing in flight, outbox flushed, and no buffered request bytes
    /// waiting to be parsed. A device mid-exchange keeps its connection
    /// until the reply is flushed; a silent idle device is cut
    /// immediately so `conns_open` can reach zero.
    fn sweep_drained(&mut self) {
        let quiescent: Vec<usize> = self
            .slots
            .iter()
            .enumerate()
            .filter_map(|(slot, s)| {
                let c = s.conn.as_ref()?;
                (c.kind == ConnKind::Proto
                    && c.in_flight == 0
                    && c.outbox.is_empty()
                    && !c.has_buffered_input())
                .then_some(slot)
            })
            .collect();
        for slot in quiescent {
            if let Some(conn) = self.slots[slot].conn.take() {
                self.release(slot, conn, false);
            }
        }
    }

    /// Route one worker completion to its connection's outbox (dropped
    /// if the connection died in the meantime — generation mismatch).
    fn route(&mut self, token: u64, reply: WireReply, stamp: Option<TraceStamp>) {
        let slot = (token >> 32) as usize;
        let gen = token as u32;
        let stale = match self.slots.get(slot) {
            Some(s) => s.gen != gen || s.conn.is_none(),
            None => true,
        };
        if stale {
            return;
        }
        {
            let conn = self.slots[slot].conn.as_mut().expect("checked live above");
            conn.in_flight = conn.in_flight.saturating_sub(1);
            conn.last_activity = Instant::now();
            if let Some(stamp) = stamp {
                // route span: worker pushed the reply → serialized into
                // this connection's outbox
                let now = self.tracer.now_us();
                self.tracer.span(stamp.trace, Stage::Route, stamp.pushed_us, now);
                conn.pending_flush.push((stamp.trace, now));
            }
            push_reply(&mut conn.outbox, reply, conn.binary);
        }
        // flush now, and parse any next request already buffered
        self.drive(slot, false);
    }

    fn accept_proto(&mut self) {
        loop {
            let stream = match self.listener.accept() {
                Ok((s, _)) => s,
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => break,
            };
            // request/response protocol: Nagle + delayed-ACK adds
            // ~40-200 ms per round trip without this
            let _ = stream.set_nodelay(true);
            if stream.set_nonblocking(true).is_err() {
                continue;
            }
            if self.drain.load(Ordering::SeqCst) {
                // graceful drain: tell the device explicitly instead of
                // letting it negotiate against a server about to exit
                Metrics::inc(&self.front.conns_rejected_total);
                let mut refusal = Vec::new();
                let _ = write_frame(
                    &mut refusal,
                    &err_resp("draining", "server draining: not accepting connections").to_line(),
                );
                let mut stream = stream;
                let _ = stream.write_all(&refusal);
                continue;
            }
            if self.proto_open >= self.max_conns {
                // refuse loudly (best effort on a fresh socket — its send
                // buffer is empty) instead of letting the device hang in
                // the backlog
                Metrics::inc(&self.front.conns_rejected_total);
                let mut refusal = Vec::new();
                let _ = write_frame(
                    &mut refusal,
                    &err_resp("max_conns", "connection limit reached").to_line(),
                );
                let mut stream = stream;
                let _ = stream.write_all(&refusal);
                continue;
            }
            Metrics::inc(&self.front.conns_accepted_total);
            let open = Metrics::gauge_inc(&self.front.conns_open);
            Metrics::observe_peak(&self.front.conns_open_peak, open);
            let mut conn = Conn::new(stream, ConnKind::Proto);
            // accept-time sampling: a sampled trace is server-side only
            // (never echoed on the wire), so enabling it cannot change
            // what any peer observes
            conn.trace = self.tracer.sink().sample_accept();
            self.insert(conn);
        }
    }

    fn accept_metrics(&mut self) {
        // drain the listener first, then register: the listener borrow
        // must not overlap the slab mutations
        let mut accepted = Vec::new();
        if let Some(listener) = &self.metrics_listener {
            loop {
                match listener.accept() {
                    Ok((stream, _)) => accepted.push(stream),
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(_) => break,
                }
            }
        }
        for stream in accepted {
            if stream.set_nonblocking(true).is_err() || self.metrics_open >= METRICS_CONN_CAP {
                continue;
            }
            // the response is deferred until the request line arrives so
            // `/trace` endpoints can be routed by path (see `step`); the
            // conn closes once the response is flushed — closing with
            // request bytes unread would RST it off the wire
            let conn = Conn::new(stream, ConnKind::Metrics);
            let slot = self.insert(conn);
            // most scrapers send immediately; try to serve in this tick
            self.drive(slot, true);
        }
    }

    fn insert(&mut self, conn: Conn) -> usize {
        match conn.kind {
            ConnKind::Proto => self.proto_open += 1,
            ConnKind::Metrics => self.metrics_open += 1,
        }
        let slot = match self.free.pop() {
            Some(s) => s,
            None => {
                self.slots.push(Slot { gen: 0, conn: None });
                self.slots.len() - 1
            }
        };
        self.slots[slot].conn = Some(conn);
        slot
    }

    /// Run one connection's state machine: optionally read, parse +
    /// dispatch while idle, flush. Closes the connection on EOF, I/O
    /// error, or a drained `closing` outbox.
    fn drive(&mut self, slot: usize, readable: bool) {
        let Some(mut conn) = self.slots.get_mut(slot).and_then(|s| s.conn.take()) else {
            return;
        };
        let token = ((slot as u64) << 32) | self.slots[slot].gen as u64;
        if self.step(&mut conn, token, readable) {
            self.slots[slot].conn = Some(conn);
        } else {
            self.release(slot, conn, false);
        }
    }

    /// The state-machine body; `true` keeps the connection.
    fn step(&mut self, conn: &mut Conn, token: u64, readable: bool) -> bool {
        if readable && conn.fill().is_err() {
            return false;
        }
        if conn.kind == ConnKind::Metrics {
            if !conn.responded {
                // route by path once the request line is complete; a
                // peer that closes (or floods) without one gets the
                // default scrape
                let path = match conn.head_line() {
                    Some(line) => Some(request_path(&line).to_owned()),
                    None if conn.peer_eof || conn.buffered_len() > 4096 => {
                        Some("/metrics".to_owned())
                    }
                    None => None,
                };
                if let Some(path) = path {
                    conn.outbox.push(self.hub.http_response(&path, self.sessions.len()));
                    conn.responded = true;
                }
            }
            if conn.responded {
                // remaining scrape input is irrelevant; never accumulate
                conn.discard_input();
            }
        }
        // the read span opens when the first byte of a request lands in
        // the buffer (closed when the frame dispatches)
        if conn.trace.is_some() && conn.read_mark.is_none() && conn.has_buffered_input() {
            conn.read_mark = Some(self.tracer.now_us());
        }
        while conn.kind == ConnKind::Proto && !conn.closing && conn.in_flight == 0 {
            match conn.next_frame() {
                Ok(Some(frame)) => self.dispatch(conn, token, frame),
                Ok(None) => break,
                Err(e) => {
                    // mirror the threaded front-end: answer bad_frame,
                    // then close once the reply is out
                    Metrics::inc(&self.front.errors_total);
                    conn.outbox.push(response_bytes(&err_resp("bad_frame", &e.to_string())));
                    conn.closing = true;
                }
            }
        }
        if conn.flush().is_err() {
            return false;
        }
        let zero_copy = conn.outbox.take_zero_copy_bytes();
        if zero_copy > 0 {
            Metrics::add(&self.front.outbox_zero_copy_bytes_total, zero_copy);
        }
        if !conn.pending_flush.is_empty() && conn.outbox.is_empty() {
            // flush span: reply queued into the outbox → last byte
            // handed to the socket
            let now = self.tracer.now_us();
            for (trace, pushed) in conn.pending_flush.drain(..) {
                self.tracer.span(trace, Stage::Flush, pushed, now);
            }
        }
        if conn.kind == ConnKind::Metrics {
            // a scrape closes once its path-routed response is queued
            // and flushed AND the request arrived (or the peer is gone)
            // — closing with request bytes still in flight would leave
            // them unread and the resulting RST could destroy the
            // response on real networks
            return !(conn.responded
                && conn.outbox.is_empty()
                && (conn.saw_input || conn.peer_eof));
        }
        if conn.closing && conn.outbox.is_empty() {
            return false;
        }
        // peer EOF closes only once everything it sent was served: no
        // reply in flight, no unflushed bytes, and no complete frame
        // left (the loop above consumed them) — a BufReader-backed
        // connection thread drains its buffer the same way before it
        // notices the close
        if conn.peer_eof && conn.in_flight == 0 && conn.outbox.is_empty() {
            return false;
        }
        true
    }

    /// Handle one parsed frame: connection-level traffic (negotiation,
    /// framing errors) is answered right here; everything else becomes a
    /// routed job for the executor pool.
    fn dispatch(&mut self, conn: &mut Conn, token: u64, frame: Frame) {
        // read span: first buffered byte of this request → frame parsed
        let parsed_us = conn.trace.map(|trace| {
            let end = self.tracer.now_us();
            let start = conn.read_mark.take().unwrap_or(end);
            self.tracer.span(trace, Stage::Read, start, end);
            end
        });
        // a binary request frame is only valid after a granted hello —
        // the server must not silently accept what it did not grant
        if matches!(frame, Frame::Binary(_)) && !conn.binary {
            Metrics::inc(&self.front.errors_total);
            conn.outbox.push(response_bytes(&err_resp(
                "bad_frame",
                "binary frame before negotiation (send hello first)",
            )));
            return;
        }
        let req = match Request::from_frame(&frame) {
            Ok(r) => r,
            Err(e) => {
                Metrics::inc(&self.front.errors_total);
                conn.outbox.push(response_bytes(&err_resp("bad_request", &e.to_string())));
                return;
            }
        };
        // framing negotiation is connection state — answered here, never
        // queued (the hello reply itself is always a JSON frame)
        if let Request::Hello(h) = &req {
            Metrics::inc(&self.front.requests_total);
            conn.binary = h.binary_frames && self.binary_allowed;
            // class-weighted fair queuing: scale this connection's
            // token-bucket rate by the declared class weight (clamped
            // inside; no-op while the limiter is disabled)
            self.fair.set_weight(token, h.weight);
            // resolve the class label once: every job this connection
            // submits carries the counter handle, so per-class
            // throttle/shed/degrade attribution is lock-free per event
            conn.class = if h.class.is_empty() {
                None
            } else {
                Some(self.hub.classes().class(&h.class))
            };
            if h.trace {
                // hello-negotiated grant: the id is echoed on the wire
                // for client-side correlation (supersedes any sampled
                // trace this connection drew at accept)
                conn.trace = Some(self.tracer.sink().grant());
            }
            conn.outbox.push(response_bytes(&Response::Hello(HelloReply {
                binary_frames: conn.binary,
                trace: conn.trace.and_then(JobTrace::wire_id),
            })));
            return;
        }
        // fair queuing: refuse before the job occupies queue capacity.
        // The token doubles as the bucket key — generation-stamped, so a
        // recycled slot starts with a fresh bucket.
        if self.fair.enabled() && !self.fair.try_admit(token) {
            Metrics::inc(&self.front.sched_throttled_total);
            if let Some(c) = &conn.class {
                Metrics::inc(&c.sched_throttled_total);
            }
            conn.outbox.push(response_bytes(&err_resp(
                "throttled",
                "fair queuing: per-connection rate exceeded",
            )));
            return;
        }
        // recorder payload pulled out before the request moves into the
        // job; only admitted requests are recorded (a shed request never
        // reached the service, so a replay should not send it either)
        let rec_infer = match &req {
            Request::Infer(i) if self.recorder.is_some() => {
                Some((i.accuracy_budget, i.channel_capacity_bps))
            }
            _ => None,
        };
        let rec_upload = self.recorder.is_some() && matches!(req, Request::Activation(_));
        match self.job_tx.try_send(
            Job::routed(req, token, Arc::clone(&self.router))
                .with_trace(conn.trace)
                .with_class(conn.class.clone()),
        ) {
            Ok(()) => {
                conn.in_flight += 1;
                if let Some(rec) = &self.recorder {
                    if let Some((budget, cap)) = rec_infer {
                        rec.record_infer(token, budget, cap);
                    } else if rec_upload {
                        rec.record_upload(token);
                    }
                }
                if let (Some(trace), Some(start)) = (conn.trace, parsed_us) {
                    // admit span: frame parsed → job enqueued (fair
                    // queuing + the queue hand-off)
                    self.tracer.span(trace, Stage::Admit, start, self.tracer.now_us());
                }
            }
            Err(TrySendError::Full(_)) => {
                Metrics::inc(&self.front.shed_total);
                conn.outbox.push(response_bytes(&err_resp(
                    "overloaded",
                    "admission control: job queue full",
                )));
            }
            Err(TrySendError::Disconnected(_)) => {
                conn.outbox.push(response_bytes(&err_resp("shutdown", "server stopping")));
                conn.closing = true;
            }
        }
    }

    /// Close connections with nothing in flight and no traffic for
    /// their idle bound: `idle_timeout` for protocol peers (slow-loris,
    /// half-open devices; zero disables), the fixed [`SCRAPE_IDLE`] for
    /// metrics scrapes that never send or never drain.
    fn sweep_idle(&mut self) {
        let now = Instant::now();
        let expired: Vec<usize> = self
            .slots
            .iter()
            .enumerate()
            .filter_map(|(slot, s)| {
                let c = s.conn.as_ref()?;
                if c.in_flight != 0 {
                    return None;
                }
                let limit = match c.kind {
                    ConnKind::Metrics => SCRAPE_IDLE,
                    ConnKind::Proto => {
                        if self.idle_timeout.is_zero() {
                            return None;
                        }
                        self.idle_timeout
                    }
                };
                (now.duration_since(c.last_activity) >= limit).then_some(slot)
            })
            .collect();
        for slot in expired {
            if let Some(conn) = self.slots[slot].conn.take() {
                self.release(slot, conn, true);
            }
        }
    }

    /// Bookkeeping for a closed connection: bump the slot generation so
    /// in-flight replies go nowhere, recycle the slot, drop the socket.
    fn release(&mut self, slot: usize, conn: Conn, timed_out: bool) {
        // drop the fair-queue bucket keyed by the dying token
        self.fair.forget(((slot as u64) << 32) | self.slots[slot].gen as u64);
        match conn.kind {
            ConnKind::Proto => {
                self.proto_open -= 1;
                Metrics::gauge_dec(&self.front.conns_open);
                if timed_out {
                    Metrics::inc(&self.front.conns_timed_out);
                }
            }
            ConnKind::Metrics => self.metrics_open -= 1,
        }
        self.slots[slot].gen = self.slots[slot].gen.wrapping_add(1);
        self.free.push(slot);
        drop(conn);
    }

}

fn err_resp(code: &str, message: &str) -> Response {
    Response::Error(ErrorReply { code: code.into(), message: message.into() })
}

/// Serialize a response in JSON-lines framing (connection-level replies
/// are always JSON, exactly like the threaded front-end's).
fn response_bytes(resp: &Response) -> Vec<u8> {
    let mut buf = Vec::new();
    let _ = write_frame(&mut buf, &resp.to_line());
    buf
}

/// Queue one worker reply into a connection's outbox in its negotiated
/// framing, without copying the encoded body: the per-connection frame
/// head (session/objective/trace stamp) is owned, the multi-megabyte
/// body rides as an `Arc<[u8]>` shared with the encoded-reply cache and
/// is written to the socket straight from where it lives
/// (`outbox_zero_copy_bytes_total`). The queued byte stream is
/// byte-identical to [`reply_bytes`] — proven by the proto splice tests
/// and the reactor≡threaded equivalence tests.
pub fn push_reply(outbox: &mut Outbox, reply: WireReply, binary: bool) {
    match reply {
        WireReply::Msg(resp) => outbox.push(response_bytes(&resp)),
        WireReply::Segment(s) => {
            if binary {
                // `None` = frame over `MAX_FRAME_BYTES`: queue nothing,
                // exactly as `write_binary_frame` refuses the same frame
                // in the copying path
                if let Some(head) =
                    s.body.binary_frame_head_stamped(s.session, s.objective, s.trace, s.degraded)
                {
                    outbox.push(head);
                    outbox.push_shared(s.body.blob_shared());
                }
            } else {
                outbox.push(s.body.json_frame_head_stamped(
                    s.session,
                    s.objective,
                    s.trace,
                    s.degraded,
                ));
                outbox.push_shared(s.body.layers_json_shared());
                outbox.push(JSON_FRAME_TAIL.to_vec());
            }
        }
    }
}

/// Serialize one worker reply in the connection's negotiated framing —
/// the nonblocking twin of the threaded front-end's `write_reply`, and
/// byte-identical to it: segment replies splice the shared encoded body.
/// The reactor's egress path is [`push_reply`] (same bytes, zero copies
/// of the body); this whole-buffer form remains the equivalence oracle
/// and the capture/recording serializer.
pub fn reply_bytes(reply: WireReply, binary: bool) -> Vec<u8> {
    let mut buf = Vec::new();
    let _ = match reply {
        WireReply::Msg(resp) => write_frame(&mut buf, &resp.to_line()),
        WireReply::Segment(s) => {
            // the stamped splice with `None`/`false` is byte-identical to
            // the untraced stamp (proven by the proto splice tests)
            if binary {
                write_binary_frame(
                    &mut buf,
                    &s.body.binary_header_stamped(s.session, s.objective, s.trace, s.degraded),
                    s.body.blob(),
                )
            } else {
                write_frame(
                    &mut buf,
                    &s.body.json_line_stamped(s.session, s.objective, s.trace, s.degraded),
                )
            }
        }
    };
    buf
}
