//! Dense tensors + the `.qt` on-disk tensor format.
//!
//! `.qt` is the interchange format between the build-time Python pipeline
//! (weights, calibration batches, test sets) and the Rust runtime. It is a
//! deliberately trivial little-endian container so both sides stay tiny:
//!
//! ```text
//! magic   4 bytes   "QTEN"
//! version u32       1
//! dtype   u32       0 = f32, 1 = i32
//! ndim    u32
//! dims    ndim × u64
//! data    prod(dims) × sizeof(dtype), little-endian, C-order
//! ```

use crate::error::{Error, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"QTEN";
const VERSION: u32 = 1;

/// Element type tags in the `.qt` header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32 = 0,
    I32 = 1,
}

/// A dense, C-order, f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    dims: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// Construct from dims + data; checks the element count.
    pub fn new(dims: Vec<usize>, data: Vec<f32>) -> Result<Tensor> {
        let n: usize = dims.iter().product();
        if n != data.len() {
            return Err(Error::Shape(format!(
                "dims {:?} imply {} elements, got {}",
                dims,
                n,
                data.len()
            )));
        }
        Ok(Tensor { dims, data })
    }

    /// All-zeros tensor.
    pub fn zeros(dims: Vec<usize>) -> Tensor {
        let n = dims.iter().product();
        Tensor { dims, data: vec![0.0; n] }
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// 2-D element access (row-major).
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.dims.len(), 2);
        self.data[i * self.dims[1] + j]
    }

    /// Reinterpret with new dims (same element count).
    pub fn reshape(mut self, dims: Vec<usize>) -> Result<Tensor> {
        let n: usize = dims.iter().product();
        if n != self.data.len() {
            return Err(Error::Shape(format!(
                "cannot reshape {} elements to {:?}",
                self.data.len(),
                dims
            )));
        }
        self.dims = dims;
        Ok(self)
    }

    /// Row `i` of a 2-D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert_eq!(self.dims.len(), 2);
        let w = self.dims[1];
        &self.data[i * w..(i + 1) * w]
    }

    /// Squared L2 norm (used by the quantization-noise model).
    pub fn sq_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    /// Min/max of the data (quantizer range). Empty tensors return (0, 0).
    pub fn min_max(&self) -> (f32, f32) {
        let mut mn = f32::INFINITY;
        let mut mx = f32::NEG_INFINITY;
        for &x in &self.data {
            mn = mn.min(x);
            mx = mx.max(x);
        }
        if self.data.is_empty() {
            (0.0, 0.0)
        } else {
            (mn, mx)
        }
    }

    /// Write in `.qt` format.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut buf = Vec::with_capacity(16 + 8 * self.dims.len() + 4 * self.data.len());
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&VERSION.to_le_bytes());
        buf.extend_from_slice(&(DType::F32 as u32).to_le_bytes());
        buf.extend_from_slice(&(self.dims.len() as u32).to_le_bytes());
        for &d in &self.dims {
            buf.extend_from_slice(&(d as u64).to_le_bytes());
        }
        for &x in &self.data {
            buf.extend_from_slice(&x.to_le_bytes());
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(&buf)?;
        Ok(())
    }

    /// Load a `.qt` file; requires dtype f32.
    pub fn load(path: impl AsRef<Path>) -> Result<Tensor> {
        let (dtype, dims, raw) = load_raw(path.as_ref())?;
        if dtype != DType::F32 {
            return Err(Error::TensorFormat(format!(
                "{}: expected f32, found {:?}",
                path.as_ref().display(),
                dtype
            )));
        }
        let data = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Tensor::new(dims, data)
    }
}

/// Load an i32 `.qt` file (class labels).
pub fn load_i32(path: impl AsRef<Path>) -> Result<(Vec<usize>, Vec<i32>)> {
    let (dtype, dims, raw) = load_raw(path.as_ref())?;
    if dtype != DType::I32 {
        return Err(Error::TensorFormat(format!(
            "{}: expected i32, found {:?}",
            path.as_ref().display(),
            dtype
        )));
    }
    let data: Vec<i32> = raw
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    let n: usize = dims.iter().product();
    if n != data.len() {
        return Err(Error::TensorFormat("element count mismatch".into()));
    }
    Ok((dims, data))
}

/// Save an i32 `.qt` file.
pub fn save_i32(path: impl AsRef<Path>, dims: &[usize], data: &[i32]) -> Result<()> {
    let n: usize = dims.iter().product();
    if n != data.len() {
        return Err(Error::Shape("element count mismatch".into()));
    }
    let mut buf = Vec::new();
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&VERSION.to_le_bytes());
    buf.extend_from_slice(&(DType::I32 as u32).to_le_bytes());
    buf.extend_from_slice(&(dims.len() as u32).to_le_bytes());
    for &d in dims {
        buf.extend_from_slice(&(d as u64).to_le_bytes());
    }
    for &x in data {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    std::fs::write(path, buf)?;
    Ok(())
}

fn load_raw(path: &Path) -> Result<(DType, Vec<usize>, Vec<u8>)> {
    let mut f = std::fs::File::open(path)
        .map_err(|e| Error::TensorFormat(format!("{}: {e}", path.display())))?;
    let mut header = [0u8; 16];
    f.read_exact(&mut header)
        .map_err(|_| Error::TensorFormat(format!("{}: truncated header", path.display())))?;
    if &header[0..4] != MAGIC {
        return Err(Error::TensorFormat(format!("{}: bad magic", path.display())));
    }
    let version = u32::from_le_bytes(header[4..8].try_into().unwrap());
    if version != VERSION {
        return Err(Error::TensorFormat(format!(
            "{}: unsupported version {version}",
            path.display()
        )));
    }
    let dtype = match u32::from_le_bytes(header[8..12].try_into().unwrap()) {
        0 => DType::F32,
        1 => DType::I32,
        d => return Err(Error::TensorFormat(format!("{}: unknown dtype {d}", path.display()))),
    };
    let ndim = u32::from_le_bytes(header[12..16].try_into().unwrap()) as usize;
    if ndim > 8 {
        return Err(Error::TensorFormat(format!("{}: ndim {ndim} too large", path.display())));
    }
    let mut dimbuf = vec![0u8; 8 * ndim];
    f.read_exact(&mut dimbuf)
        .map_err(|_| Error::TensorFormat(format!("{}: truncated dims", path.display())))?;
    let dims: Vec<usize> = dimbuf
        .chunks_exact(8)
        .map(|c| u64::from_le_bytes(c.try_into().unwrap()) as usize)
        .collect();
    let n: usize = dims.iter().product();
    if n > (1 << 31) {
        return Err(Error::TensorFormat(format!("{}: tensor too large", path.display())));
    }
    let mut raw = vec![0u8; 4 * n];
    f.read_exact(&mut raw)
        .map_err(|_| Error::TensorFormat(format!("{}: truncated data", path.display())))?;
    let mut extra = [0u8; 1];
    if f.read(&mut extra)? != 0 {
        return Err(Error::TensorFormat(format!("{}: trailing bytes", path.display())));
    }
    Ok((dtype, dims, raw))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("qpart-tensor-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_f32() {
        let t = Tensor::new(vec![2, 3], vec![1.0, -2.5, 3.0, 0.0, 1e-7, -1e7]).unwrap();
        let p = tmpfile("rt.qt");
        t.save(&p).unwrap();
        let u = Tensor::load(&p).unwrap();
        assert_eq!(t, u);
    }

    #[test]
    fn roundtrip_i32() {
        let p = tmpfile("rt_i32.qt");
        save_i32(&p, &[4], &[1, -2, 3, 2_000_000_000]).unwrap();
        let (dims, data) = load_i32(&p).unwrap();
        assert_eq!(dims, vec![4]);
        assert_eq!(data, vec![1, -2, 3, 2_000_000_000]);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(Tensor::new(vec![2, 2], vec![1.0]).is_err());
    }

    #[test]
    fn corrupted_files_rejected() {
        let p = tmpfile("bad.qt");
        std::fs::write(&p, b"NOPE").unwrap();
        assert!(Tensor::load(&p).is_err());

        // truncated data
        let t = Tensor::zeros(vec![10]);
        let good = tmpfile("good.qt");
        t.save(&good).unwrap();
        let bytes = std::fs::read(&good).unwrap();
        let trunc = tmpfile("trunc.qt");
        std::fs::write(&trunc, &bytes[..bytes.len() - 4]).unwrap();
        assert!(Tensor::load(&trunc).is_err());

        // trailing garbage
        let mut extra = bytes.clone();
        extra.push(0);
        let trail = tmpfile("trail.qt");
        std::fs::write(&trail, &extra).unwrap();
        assert!(Tensor::load(&trail).is_err());
    }

    #[test]
    fn wrong_dtype_rejected() {
        let p = tmpfile("i32_as_f32.qt");
        save_i32(&p, &[2], &[1, 2]).unwrap();
        assert!(Tensor::load(&p).is_err());
    }

    #[test]
    fn min_max_and_norm() {
        let t = Tensor::new(vec![3], vec![-1.0, 0.5, 2.0]).unwrap();
        assert_eq!(t.min_max(), (-1.0, 2.0));
        assert!((t.sq_norm() - (1.0 + 0.25 + 4.0)).abs() < 1e-12);
    }

    #[test]
    fn reshape_and_row() {
        let t = Tensor::new(vec![6], (0..6).map(|i| i as f32).collect()).unwrap();
        let t = t.reshape(vec![2, 3]).unwrap();
        assert_eq!(t.row(1), &[3.0, 4.0, 5.0]);
        assert_eq!(t.at2(0, 2), 2.0);
        assert!(t.clone().reshape(vec![4, 2]).is_err());
    }
}
