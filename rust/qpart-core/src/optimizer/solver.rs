//! Closed-form layer-wise bit-width solver.
//!
//! With the partition `p` fixed, Eq. 23 reduces to
//!
//! ```text
//! min_b  ε · Σ_l z_l·b_l    s.t.  Σ_l (s_l/ρ_l)·4^{−b_l} ≤ Δ
//! ```
//!
//! over the quantized sources `l` (weights of layers `1..=p` plus the
//! boundary activation). KKT stationarity (paper Eq. 38) gives
//! `z_l = λ·ln4·(s_l/ρ_l)·4^{−b_l}`, i.e. **every source's noise
//! contribution at the optimum is proportional to its size `z_l`** — the
//! equal-marginal-cost condition of paper Eq. 27. Substituting into the
//! active constraint yields the explicit water-filling solution
//!
//! ```text
//! b_l = log4( s_l · Σ_j z_j / (z_l · ρ_l · Δ) )
//! ```
//!
//! Notably **independent of ε** (scaling the per-bit price rescales λ but
//! not the split) — this is exactly why the paper's offline precomputation
//! (Algorithm 1) is lossless: bit-widths depend only on calibration and Δ,
//! never on the request's live channel/compute parameters.
//!
//! Practical deviations from the paper's idealized form (documented in
//! DESIGN.md §10): bit-widths are clamped to `[min_bits, max_bits]` with
//! active-set re-solving (the unconstrained formula can go below 1 bit for
//! huge tolerant layers or above 24 for touchy ones), then rounded **up**
//! to integers so the accuracy constraint still holds.

use crate::accuracy::CalibrationTable;
use crate::error::{Error, Result};
use crate::model::ModelSpec;
use crate::quant::QuantPattern;

/// One quantized source (a layer's weights, or the boundary activation).
#[derive(Debug, Clone, Copy)]
pub struct SolveItem {
    /// Element count `z_l` (the per-bit payload weight in the objective).
    pub z: f64,
    /// Noise scale `s_l` (Eq. 18).
    pub s: f64,
    /// Robustness `ρ_l(a)` (Eq. 22).
    pub rho: f64,
}

/// Bit-width bounds for the clamped solve.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BitBounds {
    pub min_bits: u8,
    pub max_bits: u8,
}

impl Default for BitBounds {
    fn default() -> Self {
        // paper's practical range: 2..16
        BitBounds { min_bits: 2, max_bits: 16 }
    }
}

/// Solver output.
#[derive(Debug, Clone)]
pub struct Solution {
    /// Continuous optimal bit-widths (clamped to bounds).
    pub bits: Vec<f64>,
    /// Integer bit-widths (rounded up, re-checked against the budget).
    pub int_bits: Vec<u8>,
    /// Σψ at the integer solution (must be ≤ 1 + tiny slack if feasible).
    pub psi_total: f64,
    /// Lagrange multiplier of the active constraint (diagnostics).
    pub lambda: f64,
}

/// Solve for bit-widths with noise budget `delta` (Eq. 23's Δ; the
/// calibration normalizes Δ = 1 ⟺ degradation = level `a`).
///
/// Errors with [`Error::Infeasible`] if even `max_bits` everywhere violates
/// the budget.
pub fn solve_bits(items: &[SolveItem], delta: f64, bounds: BitBounds) -> Result<Solution> {
    if items.is_empty() {
        return Ok(Solution { bits: vec![], int_bits: vec![], psi_total: 0.0, lambda: 0.0 });
    }
    if delta <= 0.0 {
        return Err(Error::InvalidArg("delta must be positive".into()));
    }
    for (i, it) in items.iter().enumerate() {
        if it.z <= 0.0 || it.s <= 0.0 || it.rho <= 0.0 {
            return Err(Error::InvalidArg(format!(
                "item {i}: z, s, rho must be positive (z={}, s={}, rho={})",
                it.z, it.s, it.rho
            )));
        }
    }
    let ln4 = std::f64::consts::LN_2 * 2.0;
    let psi_at = |it: &SolveItem, b: f64| (it.s / it.rho) * (-ln4 * b).exp();

    // Feasibility at the upper bound.
    let psi_min_possible: f64 = items.iter().map(|it| psi_at(it, bounds.max_bits as f64)).sum();
    if psi_min_possible > delta {
        return Err(Error::Infeasible(format!(
            "noise budget {delta:.3e} unreachable: even b={} everywhere gives Σψ={psi_min_possible:.3e}",
            bounds.max_bits
        )));
    }

    // Active-set water-filling: start all free; clamp violators; re-solve on
    // the free set with the remaining budget. Terminates in ≤ n rounds
    // because the clamped set only grows.
    #[derive(Clone, Copy, PartialEq)]
    enum State {
        Free,
        AtMin,
        AtMax,
    }
    let n = items.len();
    let mut state = vec![State::Free; n];
    let mut bits = vec![0.0f64; n];
    let mut lambda = 0.0f64;
    loop {
        let clamped_psi: f64 = items
            .iter()
            .zip(&state)
            .map(|(it, st)| match st {
                State::AtMin => psi_at(it, bounds.min_bits as f64),
                State::AtMax => psi_at(it, bounds.max_bits as f64),
                State::Free => 0.0,
            })
            .sum();
        let free_z: f64 = items
            .iter()
            .zip(&state)
            .filter(|(_, st)| **st == State::Free)
            .map(|(it, _)| it.z)
            .sum();
        let remaining = delta - clamped_psi;
        if free_z == 0.0 {
            // everything clamped
            if remaining < -1e-12 * delta {
                // min-clamps blew the budget: impossible here because
                // feasibility was checked at max_bits and AtMin only happens
                // when the unconstrained solution wanted *fewer* bits
                // (=> less noise at min than unconstrained... actually more).
                // Handle by promoting AtMin → Free is not possible; declare
                // infeasible to be safe.
                return Err(Error::Infeasible(
                    "budget exhausted by bound-clamped sources".into(),
                ));
            }
            break;
        }
        if remaining <= 0.0 {
            // Free sources have no budget: push them all to max_bits.
            for (st, _) in state.iter_mut().zip(items).filter(|(st, _)| **st == State::Free) {
                *st = State::AtMax;
            }
            continue;
        }
        // λ·ln4 = Σ_free z / remaining; b_l = log4(λ·ln4·s_l/(z_l·ρ_l))
        let lam_ln4 = free_z / remaining;
        lambda = lam_ln4 / ln4;
        let mut changed = false;
        for i in 0..n {
            if state[i] != State::Free {
                continue;
            }
            let it = &items[i];
            let b = (lam_ln4 * it.s / (it.z * it.rho)).ln() / ln4;
            if b < bounds.min_bits as f64 {
                state[i] = State::AtMin;
                changed = true;
            } else if b > bounds.max_bits as f64 {
                state[i] = State::AtMax;
                changed = true;
            } else {
                bits[i] = b;
            }
        }
        if !changed {
            break;
        }
    }
    for i in 0..n {
        bits[i] = match state[i] {
            State::AtMin => bounds.min_bits as f64,
            State::AtMax => bounds.max_bits as f64,
            State::Free => bits[i],
        };
    }

    // Integerize: rounding up strictly decreases every ψ term, so the
    // constraint stays satisfied.
    let int_bits: Vec<u8> = bits.iter().map(|&b| (b.ceil() as u8).min(bounds.max_bits)).collect();
    let psi_total: f64 = items
        .iter()
        .zip(&int_bits)
        .map(|(it, &b)| psi_at(it, b as f64))
        .sum();
    debug_assert!(psi_total <= delta * (1.0 + 1e-9) + 1e-12);

    Ok(Solution { bits, int_bits, psi_total, lambda })
}

/// Solve the bit-width pattern for model/partition/accuracy-level using a
/// calibration table. Sources are the weights of layers `1..=p` plus the
/// boundary activation at `p` (the raw input when `p = 0`); Δ = 1 by the
/// calibration's normalization.
pub fn solve_pattern(
    model: &ModelSpec,
    calib: &CalibrationTable,
    level_idx: usize,
    p: usize,
    bounds: BitBounds,
) -> Result<QuantPattern> {
    if p > model.num_layers() {
        return Err(Error::InvalidArg(format!("partition {p} > L={}", model.num_layers())));
    }
    if level_idx >= calib.levels.len() {
        return Err(Error::InvalidArg(format!("level index {level_idx} out of range")));
    }
    let mut items: Vec<SolveItem> = (1..=p)
        .map(|l| SolveItem {
            z: model.weight_params(l) as f64,
            s: calib.s_w(l),
            rho: calib.rho_w(l, level_idx),
        })
        .collect();
    items.push(SolveItem {
        z: model.activation_elems(p) as f64,
        s: calib.s_x(p),
        rho: calib.rho_x(p, level_idx),
    });
    let sol = solve_bits(&items, 1.0, bounds)?;
    let (weight_bits, act) = sol.int_bits.split_at(p);
    let pattern = QuantPattern {
        partition: p,
        weight_bits: weight_bits.to_vec(),
        activation_bits: act[0],
        accuracy_level: calib.levels[level_idx],
        predicted_degradation: calib.levels[level_idx] * sol.psi_total,
    };
    pattern.validate(model)?;
    Ok(pattern)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::mlp6;
    use crate::testing::{assert_close, check};

    const LEVELS: [f64; 5] = [0.0025, 0.005, 0.01, 0.02, 0.05];

    fn items3() -> Vec<SolveItem> {
        vec![
            SolveItem { z: 1000.0, s: 50.0, rho: 0.5 },
            SolveItem { z: 200.0, s: 5.0, rho: 0.4 },
            SolveItem { z: 50.0, s: 80.0, rho: 0.9 },
        ]
    }

    #[test]
    fn unconstrained_matches_closed_form() {
        // with wide bounds, b_l = log4(s_l·Σz/(z_l·ρ_l·Δ))
        let items = items3();
        let delta = 10.0;
        let bounds = BitBounds { min_bits: 1, max_bits: 24 };
        let sol = solve_bits(&items, delta, bounds).unwrap();
        let zsum: f64 = items.iter().map(|i| i.z).sum();
        let ln4 = std::f64::consts::LN_2 * 2.0;
        for (it, &b) in items.iter().zip(&sol.bits) {
            let expect = (it.s * zsum / (it.z * it.rho * delta)).ln() / ln4;
            assert_close(b, expect, 1e-9, 1e-9);
        }
    }

    #[test]
    fn constraint_tight_at_continuous_optimum() {
        let items = items3();
        let delta = 1.0;
        let bounds = BitBounds { min_bits: 1, max_bits: 24 };
        let sol = solve_bits(&items, delta, bounds).unwrap();
        let ln4 = std::f64::consts::LN_2 * 2.0;
        let psi: f64 = items
            .iter()
            .zip(&sol.bits)
            .map(|(it, &b)| it.s / it.rho * (-ln4 * b).exp())
            .sum();
        assert_close(psi, delta, 1e-9, 1e-6);
    }

    #[test]
    fn eq27_equal_marginals() {
        // paper Eq. 27: z_l·ρ_l / (s_l·4^{−b_l}) equal across sources
        let items = items3();
        let sol = solve_bits(&items, 1.0, BitBounds { min_bits: 1, max_bits: 24 }).unwrap();
        let ln4 = std::f64::consts::LN_2 * 2.0;
        let marginals: Vec<f64> = items
            .iter()
            .zip(&sol.bits)
            .map(|(it, &b)| it.z * it.rho / (it.s * (-ln4 * b).exp()))
            .collect();
        for m in &marginals[1..] {
            assert_close(*m, marginals[0], 1e-6, 1e-6);
        }
    }

    #[test]
    fn integer_solution_feasible() {
        let sol = solve_bits(&items3(), 1.0, BitBounds::default()).unwrap();
        assert!(sol.psi_total <= 1.0 + 1e-9);
        for b in &sol.int_bits {
            assert!((2..=16).contains(b));
        }
    }

    #[test]
    fn infeasible_detected() {
        let items = vec![SolveItem { z: 10.0, s: 1e9, rho: 1e-6 }];
        let err = solve_bits(&items, 1.0, BitBounds::default()).unwrap_err();
        assert!(matches!(err, Error::Infeasible(_)));
    }

    #[test]
    fn clamping_respects_bounds_and_budget() {
        // one source that wants ~0 bits, one that wants many
        let items = vec![
            SolveItem { z: 1e6, s: 1e-9, rho: 10.0 },  // harmless → min clamp
            SolveItem { z: 10.0, s: 1e4, rho: 0.01 },  // touchy → many bits
        ];
        let sol = solve_bits(&items, 1.0, BitBounds::default()).unwrap();
        assert_eq!(sol.int_bits[0], 2, "harmless source at min_bits");
        assert!(sol.int_bits[1] > 8);
        assert!(sol.psi_total <= 1.0 + 1e-9);
    }

    #[test]
    fn empty_and_bad_inputs() {
        assert!(solve_bits(&[], 1.0, BitBounds::default()).unwrap().int_bits.is_empty());
        assert!(solve_bits(&items3(), -1.0, BitBounds::default()).is_err());
        assert!(solve_bits(
            &[SolveItem { z: 0.0, s: 1.0, rho: 1.0 }],
            1.0,
            BitBounds::default()
        )
        .is_err());
    }

    #[test]
    fn tighter_budget_more_bits() {
        let items = items3();
        let loose = solve_bits(&items, 2.0, BitBounds { min_bits: 1, max_bits: 24 }).unwrap();
        let tight = solve_bits(&items, 0.02, BitBounds { min_bits: 1, max_bits: 24 }).unwrap();
        for (bt, bl) in tight.bits.iter().zip(&loose.bits) {
            assert!(bt > bl, "tight {bt} loose {bl}");
        }
    }

    #[test]
    fn solve_pattern_mlp6_all_partitions() {
        let m = mlp6();
        let c = CalibrationTable::synthetic(&m, &LEVELS, 11);
        for k in 0..LEVELS.len() {
            for p in 0..=m.num_layers() {
                let pat = solve_pattern(&m, &c, k, p, BitBounds::default()).unwrap();
                assert_eq!(pat.partition, p);
                assert_eq!(pat.weight_bits.len(), p);
                // the whole point: predicted degradation within the level
                assert!(
                    pat.predicted_degradation <= LEVELS[k] * (1.0 + 1e-9),
                    "k={k} p={p}: {} > {}",
                    pat.predicted_degradation,
                    LEVELS[k]
                );
            }
        }
    }

    #[test]
    fn looser_accuracy_smaller_payload() {
        // Fig. 6's shape: payload decreases as the allowed degradation grows.
        let m = mlp6();
        let c = CalibrationTable::synthetic(&m, &LEVELS, 13);
        let p = m.num_layers();
        let mut prev = u64::MAX;
        for k in 0..LEVELS.len() {
            let pat = solve_pattern(&m, &c, k, p, BitBounds::default()).unwrap();
            let z = pat.payload_bits(&m);
            assert!(z <= prev, "payload must not grow with tolerance");
            prev = z;
        }
    }

    #[test]
    fn prop_solver_feasible_and_bounded() {
        check("solver output feasible", 60, |rng| {
            let n = rng.range_usize(1, 12);
            let items: Vec<SolveItem> = (0..n)
                .map(|_| SolveItem {
                    z: rng.range_f64(1.0, 1e6),
                    s: rng.range_f64(1e-3, 1e5),
                    rho: rng.range_f64(1e-3, 1e2),
                })
                .collect();
            let delta = rng.range_f64(0.01, 10.0);
            let bounds = BitBounds::default();
            match solve_bits(&items, delta, bounds) {
                Ok(sol) => {
                    assert!(sol.psi_total <= delta * (1.0 + 1e-9) + 1e-12);
                    for &b in &sol.int_bits {
                        assert!(b >= bounds.min_bits && b <= bounds.max_bits);
                    }
                }
                Err(Error::Infeasible(_)) => {
                    // verify it really is infeasible at max bits
                    let ln4 = std::f64::consts::LN_2 * 2.0;
                    let psi: f64 = items
                        .iter()
                        .map(|it| it.s / it.rho * (-ln4 * bounds.max_bits as f64).exp())
                        .sum();
                    assert!(psi > delta);
                }
                Err(e) => panic!("unexpected error: {e}"),
            }
        });
    }
}
