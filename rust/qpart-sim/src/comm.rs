//! Communication module: wireless links between devices and the server.
//!
//! Wraps `qpart_core::channel` (Eq. 11–16) with transfer-time bookkeeping:
//! each link is half-duplex and serializes its transfers, and optionally
//! re-samples small-scale fading per coherence period.

use qpart_core::channel::{Channel, FadingChannel};

/// A device↔server link in the simulation.
#[derive(Debug, Clone)]
pub struct LinkSim {
    mode: LinkMode,
    /// Next time the link is free.
    pub busy_until: f64,
    /// Cumulative radio energy on the device side (Eq. 16).
    pub energy_j: f64,
    /// Cumulative bits moved.
    pub bits_moved: u64,
    /// Coherence period for fading links (s).
    pub coherence_s: f64,
    current: Channel,
    next_resample: f64,
}

#[derive(Debug, Clone)]
enum LinkMode {
    Fixed,
    Fading(FadingChannel),
}

impl LinkSim {
    /// Fixed-capacity link (Table II default).
    pub fn fixed(ch: Channel) -> LinkSim {
        LinkSim {
            mode: LinkMode::Fixed,
            busy_until: 0.0,
            energy_j: 0.0,
            bits_moved: 0,
            coherence_s: f64::INFINITY,
            current: ch,
            next_resample: f64::INFINITY,
        }
    }

    /// Fading link re-sampled every `coherence_s`.
    pub fn fading(mut f: FadingChannel, coherence_s: f64) -> LinkSim {
        let current = f.sample();
        LinkSim {
            mode: LinkMode::Fading(f),
            busy_until: 0.0,
            energy_j: 0.0,
            bits_moved: 0,
            coherence_s,
            current,
            next_resample: coherence_s,
        }
    }

    /// The channel as observed at `now` (what a device would report in its
    /// inference request).
    pub fn observe(&mut self, now: f64) -> Channel {
        if now >= self.next_resample {
            if let LinkMode::Fading(f) = &mut self.mode {
                self.current = f.sample();
            }
            // advance in whole coherence periods
            let periods = ((now - self.next_resample) / self.coherence_s).floor() + 1.0;
            self.next_resample += periods * self.coherence_s;
        }
        self.current
    }

    /// Transfer `bits` starting at `now`; returns the finish time and
    /// accounts device radio energy.
    pub fn transfer(&mut self, now: f64, bits: u64) -> f64 {
        let ch = self.observe(now);
        let start = now.max(self.busy_until);
        let dt = ch.tx_latency_s(bits);
        self.busy_until = start + dt;
        self.energy_j += ch.tx_energy_j(bits);
        self.bits_moved += bits;
        self.busy_until
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_link_serializes() {
        let mut l = LinkSim::fixed(Channel::fixed(200e6, 1.0));
        let t1 = l.transfer(0.0, 200_000_000); // 1 s
        assert!((t1 - 1.0).abs() < 1e-12);
        let t2 = l.transfer(0.5, 100_000_000); // queued behind
        assert!((t2 - 1.5).abs() < 1e-12);
        assert_eq!(l.bits_moved, 300_000_000);
        assert!((l.energy_j - 1.5).abs() < 1e-12);
    }

    #[test]
    fn fading_resamples_on_coherence() {
        let f = FadingChannel::new(1e6, 1.0, 1e-3, 1.0, 11);
        let mut l = LinkSim::fading(f, 1.0);
        let c0 = l.observe(0.0).capacity_bps;
        let c0b = l.observe(0.5).capacity_bps;
        assert_eq!(c0, c0b, "within coherence period: unchanged");
        let c1 = l.observe(1.5).capacity_bps;
        assert_ne!(c0, c1, "after coherence period: re-sampled");
    }

    #[test]
    fn observe_is_stable_between_periods() {
        let f = FadingChannel::new(1e6, 1.0, 1e-3, 1.0, 13);
        let mut l = LinkSim::fading(f, 2.0);
        let a = l.observe(10.0).capacity_bps;
        let b = l.observe(10.9).capacity_bps;
        assert_eq!(a, b);
    }
}
