//! **Fig. 9** — Layer-wise Time Consumption Comparison (4 schemes).
//!
//! Paper: QPART has the lowest end-to-end time at every partition point;
//! the autoencoder's extra encode/decode layers make it slowest.

mod common;

use common::*;
use qpart::prelude::*;
use qpart_bench::Table;

fn main() {
    let setup = mlp6_setup();
    banner("Fig. 9 — layer-wise total time, 4 schemes (mlp6)", setup.calibrated);
    let cost = CostModel::paper_default();
    let arch = &setup.arch;
    let list = schemes();

    let mut table = Table::new(
        "total time (s) vs partition point",
        &["p", "QPART", "No Optimization", "Model Pruning", "Auto-Encoder"],
    );
    let mut qpart_fastest = 0usize;
    for p in 0..=arch.num_layers() {
        let vals: Vec<f64> = list
            .iter()
            .map(|&s| {
                scheme_cost(s, arch, &cost, p, Some(&setup.patterns), LEVEL_1PCT)
                    .unwrap()
                    .breakdown
                    .total_time_s()
            })
            .collect();
        if vals[0] <= vals.iter().cloned().fold(f64::INFINITY, f64::min) + 1e-15 {
            qpart_fastest += 1;
        }
        table.row(
            std::iter::once(p.to_string())
                .chain(vals.iter().map(|v| format!("{v:.5}")))
                .collect(),
        );
    }
    table.print();
    println!(
        "\npaper shape: QPART fastest everywhere — holds at {}/{} points.",
        qpart_fastest,
        arch.num_layers() + 1
    );
}
