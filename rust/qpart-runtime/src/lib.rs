//! # qpart-runtime
//!
//! The Layer-3 ↔ Layer-2 bridge: loads the AOT artifacts produced by
//! `python/compile/aot.py` (HLO text + weights + calibration + datasets)
//! and executes them on the PJRT CPU client via the `xla` crate.
//!
//! * [`engine`] — PJRT client wrapper: compile HLO text files, execute with
//!   f32 literals, executable cache.
//! * [`bundle`] — the artifact bundle: manifest parsing, lazy loading of
//!   weights / calibration tables / datasets.
//! * [`executor`] — split inference: quantize-per-pattern, run the device
//!   segment through the Pallas-kernel executables, quantize the boundary
//!   activation (the simulated uplink), finish on the server segment;
//!   plus full-precision, autoencoder-baseline, and pruning-baseline paths
//!   and batched accuracy evaluation.
//!
//! Python never runs here — the HLO was lowered once at build time; this
//! crate is pure Rust + PJRT and sits on the serving hot path.

pub mod bundle;
pub mod engine;
pub mod error;
pub mod executor;

pub use bundle::{Bundle, DatasetEntry, ExecEntry, ModelEntry, ModelWeights};
pub use engine::{Engine, Exec, HostTensor};
pub use error::{Error, Result};
pub use executor::{Executor, PreparedSegment, SplitOutcome};
