"""HLO-text lowering helper (the AOT bridge to the Rust runtime).

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the published `xla` 0.1.6 crate) rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids, so text
round-trips cleanly. Lowered with `return_tuple=True`; the Rust side
unwraps with `to_tuple1()`.
"""

from __future__ import annotations

import jax
from jax._src.lib import xla_client as xc


def lower_to_hlo_text(fn, *arg_specs) -> str:
    """Lower `fn(*arg_specs) -> (out,)` to HLO text."""
    lowered = jax.jit(fn).lower(*arg_specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype="float32"):
    import jax.numpy as jnp

    return jax.ShapeDtypeStruct(tuple(shape), getattr(jnp, dtype))
