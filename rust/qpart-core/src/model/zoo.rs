//! Built-in model descriptors.
//!
//! * [`mlp6`] — the paper's Fig. 4 evaluation model: a 6-FC-layer MNIST
//!   classifier (runnable; weights produced by the Python build path).
//! * [`edgecnn`] — the CNN used for the SVHN/CIFAR10/CIFAR100 rows of
//!   Table IV (runnable, 32×32×3 input).
//! * [`tinyresnet`] — a small residual-style stack standing in for the
//!   ImageNet ResNets in the runnable experiments.
//! * [`resnet_descriptor`] — descriptor-only ResNet18/34 with the standard
//!   layer dimensions, used for Table IV's payload-compression columns
//!   (see DESIGN.md §3: no ImageNet in this environment).

use super::spec::{LayerKind, LayerSpec, ModelSpec};
use crate::error::{Error, Result};

fn lin(name: &str, d_in: usize, d_out: usize, relu: bool) -> LayerSpec {
    LayerSpec { name: name.into(), kind: LayerKind::Linear { d_in, d_out }, relu }
}

fn conv(name: &str, c_in: usize, c_out: usize, k: usize, stride: usize, in_side: usize) -> LayerSpec {
    // 'same' padding → out = ceil(in/stride); all zoo convs use odd k.
    let out_side = in_side.div_ceil(stride);
    LayerSpec {
        name: name.into(),
        kind: LayerKind::Conv2d { c_in, c_out, k, stride, in_side, out_side },
        relu: true,
    }
}

/// The paper's Fig. 4 model: 6 fully connected layers, 28×28 input,
/// 10 classes (MNIST-shaped; trained on the synthetic digit set).
pub fn mlp6() -> ModelSpec {
    ModelSpec::new(
        "mlp6",
        vec![
            lin("fc1", 784, 512, true),
            lin("fc2", 512, 256, true),
            lin("fc3", 256, 128, true),
            lin("fc4", 128, 64, true),
            lin("fc5", 64, 32, true),
            lin("fc6", 32, 10, false),
        ],
        10,
    )
    .expect("mlp6 descriptor is valid")
}

/// CNN for the 32×32×3 synthetic SVHN/CIFAR stand-ins (Table IV rows).
/// Conv trunk + 2 FC head; `num_classes` 10 or 100.
pub fn edgecnn(num_classes: usize) -> ModelSpec {
    let flat = 64 * 8 * 8;
    ModelSpec::new(
        format!("edgecnn{num_classes}"),
        vec![
            conv("conv1", 3, 16, 3, 1, 32),
            conv("conv2", 16, 32, 3, 2, 32), // 32→16
            conv("conv3", 32, 64, 3, 2, 16), // 16→8
            lin("fc1", flat, 256, true),
            lin("fc2", 256, num_classes, false),
        ],
        num_classes,
    )
    .expect("edgecnn descriptor is valid")
}

/// Small residual-style stack (runnable ImageNet stand-in, 32×32×3).
///
/// Residual adds are element-wise and contribute no MACs under the paper's
/// cost model (Eq. 2 counts only convolutions), so the descriptor lists the
/// conv/fc layers in execution order.
pub fn tinyresnet(num_classes: usize) -> ModelSpec {
    ModelSpec::new(
        "tinyresnet",
        vec![
            conv("stem", 3, 16, 3, 1, 32),
            conv("b1c1", 16, 16, 3, 1, 32),
            conv("b1c2", 16, 16, 3, 1, 32),
            conv("b2c1", 16, 32, 3, 2, 32), // 32→16
            conv("b2c2", 32, 32, 3, 1, 16),
            conv("b3c1", 32, 64, 3, 2, 16), // 16→8
            conv("b3c2", 64, 64, 3, 1, 8),
            lin("fc", 64 * 8 * 8, num_classes, false),
        ],
        num_classes,
    )
    .expect("tinyresnet descriptor is valid")
    // skips: b1c2(3) += stem(1); b2c2(5) += b2c1(4); b3c2(7) += b3c1(6)
    .with_residual(vec![(3, 1), (5, 4), (7, 6)])
    // partitions restricted to block boundaries so skips never cross the
    // device/server split (mirrors python/compile/model.py)
    .with_partitions(vec![0, 1, 3, 5, 7, 8])
}

/// Descriptor-only standard ResNet (18 or 34) at 224×224×3, 1000 classes.
/// Downsample (projection) convs are included; batch-norm parameters are
/// folded into conv bias (standard inference-time folding).
pub fn resnet_descriptor(depth: usize) -> Result<ModelSpec> {
    // blocks per stage for basic-block resnets
    let blocks: [usize; 4] = match depth {
        18 => [2, 2, 2, 2],
        34 => [3, 4, 6, 3],
        _ => return Err(Error::InvalidArg(format!("resnet_descriptor: depth {depth} not supported"))),
    };
    let mut layers = Vec::new();
    // stem: 7x7/2 conv 3→64 on 224 → 112, then 3x3/2 maxpool → 56
    layers.push(LayerSpec {
        name: "conv1".into(),
        kind: LayerKind::Conv2d { c_in: 3, c_out: 64, k: 7, stride: 2, in_side: 224, out_side: 112 },
        relu: true,
    });
    let stage_channels = [64usize, 128, 256, 512];
    // feature-map side at the *input* of each stage (after the stem maxpool)
    let mut side = 56usize;
    let mut c_in = 64usize;
    for (s, (&c_out, &nblocks)) in stage_channels.iter().zip(blocks.iter()).enumerate() {
        for b in 0..nblocks {
            let stride = if s > 0 && b == 0 { 2 } else { 1 };
            let out_side = side / stride;
            layers.push(LayerSpec {
                name: format!("s{}b{}c1", s + 1, b + 1),
                kind: LayerKind::Conv2d { c_in, c_out, k: 3, stride, in_side: side, out_side },
                relu: true,
            });
            layers.push(LayerSpec {
                name: format!("s{}b{}c2", s + 1, b + 1),
                kind: LayerKind::Conv2d {
                    c_in: c_out, c_out, k: 3, stride: 1, in_side: out_side, out_side,
                },
                relu: true,
            });
            side = out_side;
            c_in = c_out;
        }
    }
    // global average pool (no params) then fc
    layers.push(lin("fc", 512, 1000, false));
    // NOTE: projection shortcuts (1x1) omitted from the descriptor: they are
    // <3% of parameters and the paper's Eq. 2 accounting; the fc input of 512
    // relies on global average pooling collapsing the 7x7 map.
    let l = layers.len();
    let input_shape = vec![3, 224, 224];
    let spec = ModelSpec {
        name: format!("resnet{depth}"),
        layers,
        num_classes: 1000,
        partition_points: (0..=l).collect(),
        input_shape,
        residual: Vec::new(),
    };
    // Descriptor-only: inter-layer activation counts do not chain through
    // pooling layers, so skip `validate()` (documented deviation).
    Ok(spec)
}

/// Look up any built-in descriptor by name.
pub fn builtin(name: &str) -> Result<ModelSpec> {
    match name {
        "mlp6" => Ok(mlp6()),
        "edgecnn10" => Ok(edgecnn(10)),
        "edgecnn100" => Ok(edgecnn(100)),
        "tinyresnet" => Ok(tinyresnet(10)),
        "resnet18" => resnet_descriptor(18),
        "resnet34" => resnet_descriptor(34),
        _ => Err(Error::NotFound(format!("no builtin model '{name}'"))),
    }
}

/// Names accepted by [`builtin`].
pub fn builtin_names() -> &'static [&'static str] {
    &["mlp6", "edgecnn10", "edgecnn100", "tinyresnet", "resnet18", "resnet34"]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mlp6_matches_fig4() {
        let m = mlp6();
        assert_eq!(m.num_layers(), 6);
        assert_eq!(m.activation_elems(0), 784);
        assert_eq!(m.activation_elems(6), 10);
        // params: Σ d_in*d_out + d_out
        let expect: u64 = [(784, 512), (512, 256), (256, 128), (128, 64), (64, 32), (32, 10)]
            .iter()
            .map(|&(i, o)| (i * o + o) as u64)
            .sum();
        assert_eq!(m.total_params(), expect);
    }

    #[test]
    fn all_builtins_resolve() {
        for name in builtin_names() {
            let m = builtin(name).unwrap();
            assert!(m.total_params() > 0);
            assert!(m.total_macs() > 0);
        }
        assert!(builtin("nope").is_err());
    }

    #[test]
    fn runnable_models_validate() {
        mlp6().validate().unwrap();
        edgecnn(10).validate().unwrap();
        edgecnn(100).validate().unwrap();
        tinyresnet(10).validate().unwrap();
    }

    #[test]
    fn resnet18_param_count_sane() {
        // Standard ResNet18 ≈ 11.7M params; without 1x1 projection shortcuts
        // and with bn folded we expect slightly less but the same order.
        let m = resnet_descriptor(18).unwrap();
        let p = m.total_params();
        assert!((10_000_000..12_500_000).contains(&p), "params={p}");
        let m34 = resnet_descriptor(34).unwrap();
        assert!(m34.total_params() > p);
    }

    #[test]
    fn edgecnn_spatial_chain() {
        let m = edgecnn(10);
        // conv3 output 8×8×64 must equal fc1 input
        assert_eq!(m.layers[2].activation_elems(), 64 * 8 * 8);
    }
}
