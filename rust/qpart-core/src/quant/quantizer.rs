//! Uniform asymmetric quantizer (paper Eq. 9–10).
//!
//! For a real value `c` and bit-width `b`, the quantization set is the
//! uniform grid of `2^b` points on `[μ, φ]` (Eq. 9); `Q(c)` maps `c` to the
//! nearest grid point (Eq. 10). We store grid *indices* (codes); the wire
//! carries codes bit-packed at `b` bits each plus the `(μ, φ, b)` header,
//! and the device reconstructs `ĉ = μ + code·Δ` with `Δ = (φ−μ)/(2^b−1)`.
//!
//! The serving hot path never needs the intermediate code vector — it
//! quantizes a layer only to bit-pack it for the wire — so
//! [`quantize_packed`] fuses Eq. 10 with the packer: `&[f32]` → packed
//! bytes in one pass, bit-identical to `quantize_with` ∘ `pack_bits`.

use crate::error::{Error, Result};
use crate::quant::bitpack::{packed_len_bytes, WordPacker};
use crate::quant::simd;

/// Quantizer parameters: bit-width and range.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuantParams {
    /// Bit-width `b ∈ 1..=24` (codes fit u32; the paper uses 2..16).
    pub bits: u8,
    /// Grid minimum μ.
    pub min: f32,
    /// Grid maximum φ.
    pub max: f32,
}

impl QuantParams {
    /// Derive parameters from data range. A degenerate range (all values
    /// equal) widens to a tiny symmetric interval so Δ > 0.
    pub fn from_range(bits: u8, min: f32, max: f32) -> Result<QuantParams> {
        if !(1..=24).contains(&bits) {
            return Err(Error::InvalidArg(format!("bits must be in 1..=24, got {bits}")));
        }
        if !min.is_finite() || !max.is_finite() || min > max {
            return Err(Error::InvalidArg(format!("invalid range [{min}, {max}]")));
        }
        let (min, max) = if min == max {
            (min - 1e-6, max + 1e-6)
        } else {
            (min, max)
        };
        Ok(QuantParams { bits, min, max })
    }

    /// Grid step `Δ = (φ−μ)/(2^b−1)`.
    pub fn step(&self) -> f32 {
        (self.max - self.min) / ((1u32 << self.bits) - 1) as f32
    }

    /// Number of grid levels `2^b`.
    pub fn levels(&self) -> u32 {
        1u32 << self.bits
    }
}

/// A quantized buffer: codes + parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct Quantized {
    pub params: QuantParams,
    /// Grid indices in `0..levels()`.
    pub codes: Vec<u32>,
}

impl Quantized {
    /// Reconstruct the real values.
    pub fn dequantize(&self) -> Vec<f32> {
        dequantize(&self.codes, self.params)
    }

    /// Payload size in bits when bit-packed for the wire (codes only;
    /// the (μ, φ, b) header is constant per layer and negligible).
    pub fn payload_bits(&self) -> u64 {
        self.codes.len() as u64 * self.params.bits as u64
    }
}

/// Branch-free range scan shared by [`quantize`] and [`quantize_packed`]
/// (the per-element `is_finite` check halved throughput; see perf_quant).
/// ±inf surfaces in mn/mx; NaN — which IEEE min/max would silently skip
/// — is caught by the checksum. Empty input scans to `(0, 0)`.
pub(crate) fn scan_range(data: &[f32]) -> Result<(f32, f32)> {
    let (mut mn, mut mx) = (f32::INFINITY, f32::NEG_INFINITY);
    let mut checksum = 0.0f32;
    for &x in data {
        mn = mn.min(x);
        mx = mx.max(x);
        checksum += x * 0.0; // 0·x is NaN iff x is NaN or ±inf
    }
    if !checksum.eq(&0.0) || (!data.is_empty() && (!mn.is_finite() || !mx.is_finite())) {
        return Err(Error::InvalidArg("non-finite value in quantize input".into()));
    }
    if data.is_empty() {
        return Ok((0.0, 0.0));
    }
    Ok((mn, mx))
}

/// Quantize `data` at `bits`, deriving the range from the data (the paper's
/// post-training setting: μ/φ are the observed min/max of the layer).
pub fn quantize(data: &[f32], bits: u8) -> Result<Quantized> {
    let (mn, mx) = scan_range(data)?;
    let params = QuantParams::from_range(bits, mn, mx)?;
    Ok(quantize_with(data, params))
}

/// Quantize with explicit parameters (Eq. 10: nearest grid point, clamped).
///
/// Hot path (per-request, O(params)) — see `perf_quant`. The inner loop is
/// written for the vectorizer: `(x−μ)·inv + 0.5` truncated by the
/// saturating float→int cast (negatives clamp to 0), then a min against
/// the top code. `round()` (half-away-from-even tie logic) measured ~2×
/// slower; ties land on grid midpoints where either neighbor is an equally
/// valid Eq. 10 argmin.
pub fn quantize_with(data: &[f32], params: QuantParams) -> Quantized {
    let step = params.step();
    let inv = 1.0 / step;
    let min = params.min;
    let max_code = params.levels() - 1;
    let mut codes = Vec::with_capacity(data.len());
    codes.extend(data.iter().map(|&x| {
        // saturating cast: NaN→0, negative→0, huge→u32::MAX
        let q = ((x - min) * inv + 0.5) as u32;
        q.min(max_code)
    }));
    Quantized { params, codes }
}

/// Reconstruct values from codes.
pub fn dequantize(codes: &[u32], params: QuantParams) -> Vec<f32> {
    let step = params.step();
    codes.iter().map(|&c| params.min + c as f32 * step).collect()
}

/// A quantized buffer already bit-packed for the wire: what the fused
/// [`quantize_packed`] kernel produces. Carries everything a reply header
/// needs (`(μ, Δ, b)` + code count) without ever materializing the code
/// vector.
#[derive(Debug, Clone, PartialEq)]
pub struct PackedQuantized {
    pub params: QuantParams,
    /// Number of packed codes (needed to unpack: the byte length alone is
    /// ambiguous for sub-byte widths).
    pub len: usize,
    /// LSB-first bit-packed codes, `packed_len_bytes(len, bits)` bytes.
    pub packed: Vec<u8>,
}

impl PackedQuantized {
    /// Payload size in bits when on the wire (codes only, as in
    /// [`Quantized::payload_bits`]).
    pub fn payload_bits(&self) -> u64 {
        self.len as u64 * self.params.bits as u64
    }
}

/// Fused quantize→pack with data-derived range (the fused analogue of
/// [`quantize`]): one pass over `data` computes each Eq. 10 code and
/// streams it straight into the bit-packer's word accumulator. No
/// intermediate `Vec<u32>` — the allocation and the second sweep the
/// compose-then-pack path pays per layer.
///
/// Dispatching entry point: runs the SIMD lanes when the process-wide
/// [`simd::active`] mode is a vector tier (see [`crate::quant::simd`]),
/// the word-wise kernel otherwise. Bit-identical either way to
/// `pack_bits(&quantize(data, bits)?.codes, bits)?` (property-tested);
/// `bits` is capped at 24 by the packer.
pub fn quantize_packed(data: &[f32], bits: u8) -> Result<PackedQuantized> {
    let (mn, mx) = scan_range(data)?;
    let params = QuantParams::from_range(bits, mn, mx)?;
    Ok(quantize_packed_with(data, params))
}

/// Fused quantize→pack with explicit parameters (the fused analogue of
/// [`quantize_with`] ∘ [`crate::quant::pack_bits`]). Dispatches like
/// [`quantize_packed`].
pub fn quantize_packed_with(data: &[f32], params: QuantParams) -> PackedQuantized {
    if simd::active().is_simd() {
        simd::quantize_packed_with_simd(data, params)
    } else {
        quantize_packed_with_wordwise(data, params)
    }
}

/// Word-wise fused quantize→pack with data-derived range — the PR 4
/// kernel, kept as the SIMD oracle and runtime fallback.
pub fn quantize_packed_wordwise(data: &[f32], bits: u8) -> Result<PackedQuantized> {
    let (mn, mx) = scan_range(data)?;
    let params = QuantParams::from_range(bits, mn, mx)?;
    Ok(quantize_packed_with_wordwise(data, params))
}

/// Word-wise fused quantize→pack with explicit parameters. Codes fit
/// `bits` by construction (the Eq. 10 clamp), so no validation scan is
/// needed; the emit loop is the same `WordPacker` accumulator `pack_bits`
/// uses, fed by the quantizer instead of a code slice. The oracle every
/// SIMD quantize kernel must match byte-for-byte.
pub fn quantize_packed_with_wordwise(data: &[f32], params: QuantParams) -> PackedQuantized {
    let step = params.step();
    let inv = 1.0 / step;
    let min = params.min;
    let max_code = params.levels() - 1;
    let bits = params.bits as u32;
    let mut packed = vec![0u8; packed_len_bytes(data.len(), params.bits)];
    let mut packer = WordPacker::new(&mut packed);
    for &x in data {
        // Eq. 10 via saturating cast: NaN→0, negative→0, huge→u32::MAX
        let q = (((x - min) * inv + 0.5) as u32).min(max_code);
        packer.push(q, bits);
    }
    packer.finish();
    PackedQuantized { params, len: data.len(), packed }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, vec_f32};

    #[test]
    fn error_bounded_by_half_step() {
        let data: Vec<f32> = (0..1000).map(|i| (i as f32 * 0.37).sin() * 3.0).collect();
        for bits in [2u8, 4, 8, 12] {
            let q = quantize(&data, bits).unwrap();
            let d = q.dequantize();
            let half = q.params.step() / 2.0;
            for (a, b) in data.iter().zip(&d) {
                assert!(
                    (a - b).abs() <= half * 1.0001,
                    "bits={bits} a={a} b={b} half={half}"
                );
            }
        }
    }

    #[test]
    fn range_endpoints_exact() {
        let data = [-2.0f32, 0.1, 2.0];
        let q = quantize(&data, 8).unwrap();
        let d = q.dequantize();
        assert!((d[0] + 2.0).abs() < 1e-6);
        assert!((d[2] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn one_bit_two_levels() {
        let data = [0.0f32, 0.2, 0.8, 1.0];
        let q = quantize(&data, 1).unwrap();
        assert_eq!(q.codes, vec![0, 0, 1, 1]);
        assert_eq!(q.params.levels(), 2);
    }

    #[test]
    fn constant_input_survives() {
        let data = [3.5f32; 16];
        let q = quantize(&data, 4).unwrap();
        let d = q.dequantize();
        for x in d {
            assert!((x - 3.5).abs() < 1e-4);
        }
    }

    #[test]
    fn empty_input_ok() {
        let q = quantize(&[], 8).unwrap();
        assert!(q.codes.is_empty());
        assert_eq!(q.payload_bits(), 0);
    }

    #[test]
    fn rejects_bad_inputs() {
        assert!(quantize(&[f32::NAN], 8).is_err());
        assert!(quantize(&[1.0], 0).is_err());
        assert!(quantize(&[1.0], 25).is_err());
        assert!(QuantParams::from_range(8, 2.0, 1.0).is_err());
    }

    #[test]
    fn noise_energy_scales_as_4_pow_minus_b() {
        // ||σ||² = s · 4^{-b} (paper Eq. 18): uniform quantization noise has
        // variance Δ²/12 with Δ ∝ 2^{-b}, so energy halves 4× per extra bit.
        let data: Vec<f32> = (0..20_000).map(|i| ((i as f32) * 0.7133).sin()).collect();
        let energy = |bits: u8| {
            let q = quantize(&data, bits).unwrap();
            let d = q.dequantize();
            data.iter().zip(&d).map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>()
        };
        let (e6, e8) = (energy(6), energy(8));
        let ratio = e6 / e8;
        // expect ≈ 4^2 = 16 (tolerate grid effects)
        assert!((10.0..24.0).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn prop_roundtrip_error_bound() {
        check("quantize error ≤ half step", 60, |rng| {
            let len = rng.range_usize(1, 300);
            let lo = rng.range_f64(-50.0, 0.0) as f32;
            let hi = lo + rng.range_f64(0.001, 100.0) as f32;
            let data = vec_f32(rng, len, lo, hi);
            let bits = rng.range_usize(1, 17) as u8;
            let q = quantize(&data, bits).unwrap();
            let d = q.dequantize();
            let half = q.params.step() / 2.0 + 1e-5;
            for (a, b) in data.iter().zip(&d) {
                assert!((a - b).abs() <= half, "a={a} b={b} half={half}");
            }
        });
    }

    #[test]
    fn prop_fused_quantize_packed_matches_compose() {
        use crate::quant::pack_bits;
        // the fused kernel must be a drop-in for quantize(_with) ∘ pack_bits:
        // same params, same byte stream, for every width 1..=24
        check("quantize_packed ≡ quantize∘pack", 80, |rng| {
            let len = rng.range_usize(0, 400);
            let lo = rng.range_f64(-50.0, 0.0) as f32;
            let hi = lo + rng.range_f64(0.001, 100.0) as f32;
            let data = vec_f32(rng, len, lo, hi);
            let bits = rng.range_usize(1, 25) as u8;
            let q = quantize(&data, bits).unwrap();
            let composed = pack_bits(&q.codes, bits).unwrap();
            let fused = quantize_packed(&data, bits).unwrap();
            assert_eq!(fused.params, q.params, "bits={bits} len={len}");
            assert_eq!(fused.len, q.codes.len());
            assert_eq!(fused.packed, composed, "bits={bits} len={len}");
            assert_eq!(fused.payload_bits(), q.payload_bits());
            // and explicit-params fusion agrees too
            let fused_with = quantize_packed_with(&data, q.params);
            assert_eq!(fused_with.packed, composed);
            // the retained word-wise oracle stays byte-identical regardless
            // of what the dispatcher selected above
            let word = quantize_packed_wordwise(&data, bits).unwrap();
            assert_eq!(word, fused, "bits={bits} len={len}");
        });
    }

    #[test]
    fn fused_all_widths_dense() {
        use crate::quant::pack_bits;
        let data: Vec<f32> = (0..321).map(|i| ((i as f32) * 0.7133).sin() * 2.5).collect();
        for bits in 1u8..=24 {
            let q = quantize(&data, bits).unwrap();
            let fused = quantize_packed(&data, bits).unwrap();
            assert_eq!(fused.packed, pack_bits(&q.codes, bits).unwrap(), "bits={bits}");
        }
    }

    #[test]
    fn fused_rejects_bad_inputs_like_quantize() {
        assert!(quantize_packed(&[f32::NAN], 8).is_err());
        assert!(quantize_packed(&[1.0], 0).is_err());
        assert!(quantize_packed(&[1.0], 25).is_err());
        let empty = quantize_packed(&[], 8).unwrap();
        assert!(empty.packed.is_empty());
        assert_eq!(empty.payload_bits(), 0);
    }

    #[test]
    fn prop_codes_in_range() {
        check("codes within levels", 40, |rng| {
            let len = rng.range_usize(1, 100);
            let data = vec_f32(rng, len, -10.0, 10.0);
            let bits = rng.range_usize(1, 13) as u8;
            let q = quantize(&data, bits).unwrap();
            for &c in &q.codes {
                assert!(c < q.params.levels());
            }
        });
    }
}
