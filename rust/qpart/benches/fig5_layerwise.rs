//! **Fig. 5** — Layer-wise Performance Comparison.
//!
//! Paper: as the partition point moves toward the output layer, time and
//! device energy rise (more weights over the wire) while server cost falls
//! (less server compute); QPART sits far below the unoptimized service at
//! every partition point. Three panels: total time, device energy, server
//! cost — each QPART vs No-Optimization over p = 0..L.

mod common;

use common::*;
use qpart::prelude::*;
use qpart_bench::{fmt_si, Table};

fn main() {
    let setup = mlp6_setup();
    banner("Fig. 5 — layer-wise time / energy / server-cost (mlp6)", setup.calibrated);
    let cost = CostModel::paper_default();
    let arch = &setup.arch;

    let mut t = Table::new(
        "panel 1: total time (s) vs partition point",
        &["p", "QPART", "No Optimization", "speedup"],
    );
    let mut e = Table::new(
        "panel 2: device energy (J) vs partition point",
        &["p", "QPART", "No Optimization", "saving"],
    );
    let mut c = Table::new(
        "panel 3: server cost vs partition point",
        &["p", "QPART", "No Optimization"],
    );
    for p in 0..=arch.num_layers() {
        let q = scheme_cost(Scheme::Qpart, arch, &cost, p, Some(&setup.patterns), LEVEL_1PCT)
            .unwrap();
        let n = scheme_cost(Scheme::NoOpt, arch, &cost, p, None, 0).unwrap();
        let (qt, nt) = (q.breakdown.total_time_s(), n.breakdown.total_time_s());
        t.row(vec![
            p.to_string(),
            format!("{qt:.5}"),
            format!("{nt:.5}"),
            format!("{:.1}x", nt / qt),
        ]);
        let (qe, ne) = (q.breakdown.total_energy_j(), n.breakdown.total_energy_j());
        e.row(vec![
            p.to_string(),
            fmt_si(qe),
            fmt_si(ne),
            format!("{:.1}x", ne / qe),
        ]);
        c.row(vec![
            p.to_string(),
            fmt_si(q.breakdown.server_cost),
            fmt_si(n.breakdown.server_cost),
        ]);
    }
    t.print();
    e.print();
    c.print();
    println!(
        "\npaper shapes: time+energy increase with p, server cost decreases with p, \
         QPART ≪ No-Optimization at every p."
    );
}
