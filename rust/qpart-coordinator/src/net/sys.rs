//! Thin readiness primitives for the reactor: a `poll(2)` wrapper over
//! raw fds, a vectored `writev(2)` wrapper for gathered egress, and a
//! self-wake channel, all built on std + two libc symbols (no mio/libc
//! crates — the workspace stays dependency-free).
//!
//! `poll(2)` is the portable-unix readiness syscall: level-triggered, no
//! registration state in the kernel, one array of `(fd, interest)` per
//! call. At coordinator scale (thousands of connections, one reactor
//! thread) the O(n) fd scan per wakeup is noise next to inference work,
//! and level-triggering keeps the state machine simple — a connection
//! with buffered input or a non-empty outbox is simply polled again next
//! tick.
//!
//! The [`Waker`] exists because worker threads finish jobs while the
//! reactor is parked inside `poll`: pushing a completion must interrupt
//! the park. It is a connected nonblocking UDP socket pair on loopback —
//! `wake()` sends a one-byte datagram to the receive socket whose fd the
//! reactor polls for readability. A full socket buffer just means wakes
//! are already pending, so dropped datagrams are harmless by
//! construction.

use std::io;
use std::net::UdpSocket;
use std::os::raw::c_int;
use std::os::unix::io::{AsRawFd, RawFd};

/// `nfds_t`: `unsigned long` on Linux, `unsigned int` on the BSDs and
/// macOS — the extern signature must match the target's ABI type, not
/// just something register-compatible.
#[cfg(target_os = "linux")]
type Nfds = std::os::raw::c_ulong;
#[cfg(not(target_os = "linux"))]
type Nfds = std::os::raw::c_uint;

/// One entry of the `poll(2)` fd array (`struct pollfd`).
#[repr(C)]
#[derive(Debug, Clone, Copy)]
pub struct PollFd {
    pub fd: RawFd,
    pub events: i16,
    pub revents: i16,
}

impl PollFd {
    pub fn new(fd: RawFd, events: i16) -> PollFd {
        PollFd { fd, events, revents: 0 }
    }

    /// Any readiness at all (including error/hang-up, which the kernel
    /// reports regardless of the requested interest set).
    pub fn ready(&self) -> bool {
        self.revents != 0
    }

    pub fn readable(&self) -> bool {
        self.revents & (POLLIN | POLLHUP | POLLERR) != 0
    }

    pub fn writable(&self) -> bool {
        self.revents & POLLOUT != 0
    }

    /// The fd is dead or was never valid: close, don't retry.
    pub fn broken(&self) -> bool {
        self.revents & (POLLERR | POLLNVAL) != 0
    }
}

// Identical values on Linux and the BSDs/macOS.
pub const POLLIN: i16 = 0x001;
pub const POLLOUT: i16 = 0x004;
pub const POLLERR: i16 = 0x008;
pub const POLLHUP: i16 = 0x010;
pub const POLLNVAL: i16 = 0x020;

/// One gather segment of a `writev(2)` call (`struct iovec`): base
/// pointer first, then length, on every unix libc. Carries the borrow's
/// lifetime (like `std::io::IoSlice`) so a vector of these cannot
/// outlive the buffers it points into.
#[repr(C)]
#[derive(Debug, Clone, Copy)]
pub struct IoVec<'a> {
    base: *const u8,
    len: usize,
    _buf: std::marker::PhantomData<&'a [u8]>,
}

impl<'a> IoVec<'a> {
    pub fn new(slice: &'a [u8]) -> IoVec<'a> {
        IoVec { base: slice.as_ptr(), len: slice.len(), _buf: std::marker::PhantomData }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

extern "C" {
    fn poll(fds: *mut PollFd, nfds: Nfds, timeout: c_int) -> c_int;
    fn writev(fd: c_int, iov: *const IoVec<'_>, iovcnt: c_int) -> isize;
    fn signal(signum: c_int, handler: usize) -> usize;
}

/// `SIGINT` / `SIGTERM` numbers — identical on Linux and the BSDs/macOS,
/// like the poll constants above.
const SIGINT: c_int = 2;
const SIGTERM: c_int = 15;

/// Set by the signal handler; read by [`shutdown_requested`].
static SHUTDOWN: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(false);

/// The actual handler: only an atomic store, the strictest
/// async-signal-safe discipline — everything else (draining, flushing,
/// exiting) happens on normal threads that poll [`shutdown_requested`].
extern "C" fn on_shutdown_signal(_signum: c_int) {
    SHUTDOWN.store(true, std::sync::atomic::Ordering::SeqCst);
}

/// Install the graceful-drain handler for `SIGTERM` and `SIGINT`. After
/// this, either signal flips the [`shutdown_requested`] flag instead of
/// killing the process; callers are expected to poll the flag and drain.
/// Idempotent. `signal(2)` rather than `sigaction` keeps this to one
/// universal libc symbol; the handler stays installed across deliveries
/// on every modern unix (BSD semantics), and even one delivery is all a
/// drain needs.
pub fn install_shutdown_handler() {
    let handler = on_shutdown_signal as extern "C" fn(c_int) as usize;
    unsafe {
        signal(SIGTERM, handler);
        signal(SIGINT, handler);
    }
}

/// Whether a shutdown signal has arrived since
/// [`install_shutdown_handler`]. Test hooks may also set this via
/// [`request_shutdown`].
pub fn shutdown_requested() -> bool {
    SHUTDOWN.load(std::sync::atomic::Ordering::SeqCst)
}

/// Flip the shutdown flag from code (tests, or an admin path) — exactly
/// what a delivered `SIGTERM` would do.
pub fn request_shutdown() {
    SHUTDOWN.store(true, std::sync::atomic::Ordering::SeqCst);
}

/// Gathered write to a stream fd: one syscall for many queued buffers,
/// so shared reply bodies are handed to the kernel straight from where
/// they live instead of being copied into a contiguous staging buffer.
/// Returns the bytes accepted (possibly a short count spanning only part
/// of the iovec list). `EINTR` retries internally; a nonblocking fd with
/// a full socket buffer surfaces as `WouldBlock` like `Write::write`.
pub fn writev_stream(fd: RawFd, iovs: &[IoVec<'_>]) -> io::Result<usize> {
    if iovs.is_empty() {
        return Ok(0);
    }
    // Portable floor of IOV_MAX (POSIX requires ≥ 16; every modern unix
    // has 1024). Callers batch well below this; clamp defensively.
    let cnt = iovs.len().min(1024) as c_int;
    loop {
        let rc = unsafe { writev(fd, iovs.as_ptr(), cnt) };
        if rc >= 0 {
            return Ok(rc as usize);
        }
        let err = io::Error::last_os_error();
        if err.kind() != io::ErrorKind::Interrupted {
            return Err(err);
        }
    }
}

/// Block until at least one fd is ready or `timeout_ms` elapses
/// (`0` = return immediately, negative = wait forever). Returns how many
/// entries have non-zero `revents`. `EINTR` retries internally.
pub fn poll_fds(fds: &mut [PollFd], timeout_ms: i32) -> io::Result<usize> {
    loop {
        let rc = unsafe { poll(fds.as_mut_ptr(), fds.len() as Nfds, timeout_ms) };
        if rc >= 0 {
            return Ok(rc as usize);
        }
        let err = io::Error::last_os_error();
        if err.kind() != io::ErrorKind::Interrupted {
            return Err(err);
        }
    }
}

/// Cross-thread wakeup for a reactor parked in [`poll_fds`].
#[derive(Debug)]
pub struct Waker {
    tx: UdpSocket,
    rx: UdpSocket,
}

impl Waker {
    pub fn new() -> io::Result<Waker> {
        let rx = UdpSocket::bind("127.0.0.1:0")?;
        rx.set_nonblocking(true)?;
        let tx = UdpSocket::bind("127.0.0.1:0")?;
        tx.set_nonblocking(true)?;
        tx.connect(rx.local_addr()?)?;
        Ok(Waker { tx, rx })
    }

    /// Nudge the reactor (safe from any thread; never blocks). A send
    /// that fails because the buffer is full means wakes are already
    /// pending — exactly the state we wanted.
    pub fn wake(&self) {
        let _ = self.tx.send(&[1u8]);
    }

    /// Swallow every pending wake datagram (reactor thread, after poll).
    pub fn drain(&self) {
        let mut buf = [0u8; 16];
        while self.rx.recv(&mut buf).is_ok() {}
    }

    /// The fd the reactor registers with `POLLIN` interest.
    pub fn fd(&self) -> RawFd {
        self.rx.as_raw_fd()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poll_times_out_with_nothing_ready() {
        let waker = Waker::new().unwrap();
        let mut fds = [PollFd::new(waker.fd(), POLLIN)];
        let n = poll_fds(&mut fds, 10).unwrap();
        assert_eq!(n, 0);
        assert!(!fds[0].ready());
    }

    #[test]
    fn wake_makes_the_fd_readable_until_drained() {
        let waker = Waker::new().unwrap();
        waker.wake();
        waker.wake(); // coalescing duplicates is fine
        let mut fds = [PollFd::new(waker.fd(), POLLIN)];
        let n = poll_fds(&mut fds, 1000).unwrap();
        assert_eq!(n, 1);
        assert!(fds[0].readable());
        waker.drain();
        let mut fds = [PollFd::new(waker.fd(), POLLIN)];
        assert_eq!(poll_fds(&mut fds, 10).unwrap(), 0, "drained waker is quiet");
    }

    #[test]
    fn writev_gathers_across_buffers() {
        use std::io::Read;
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let mut client = std::net::TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let (server_side, _) = listener.accept().unwrap();
        let parts: [&[u8]; 4] = [b"alpha ", b"", b"beta ", b"gamma"];
        let iovs: Vec<IoVec<'_>> = parts.iter().map(|p| IoVec::new(p)).collect();
        let total: usize = parts.iter().map(|p| p.len()).sum();
        let n = writev_stream(server_side.as_raw_fd(), &iovs).unwrap();
        assert_eq!(n, total, "small gather lands in one call");
        drop(server_side);
        let mut got = Vec::new();
        client.read_to_end(&mut got).unwrap();
        assert_eq!(got, b"alpha beta gamma");
    }

    #[test]
    fn writev_reports_would_block_on_full_nonblocking_socket() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let client = std::net::TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let (server_side, _) = listener.accept().unwrap();
        server_side.set_nonblocking(true).unwrap();
        // nobody reads `client`, so the send buffer eventually fills
        let chunk = vec![0u8; 256 * 1024];
        let iovs = [IoVec::new(&chunk)];
        let mut saw_would_block = false;
        for _ in 0..256 {
            match writev_stream(server_side.as_raw_fd(), &iovs) {
                Ok(_) => {}
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    saw_would_block = true;
                    break;
                }
                Err(e) => panic!("unexpected error: {e}"),
            }
        }
        assert!(saw_would_block, "a full socket buffer must surface as WouldBlock");
        drop(client);
    }

    #[test]
    fn poll_reports_readable_tcp_data() {
        use std::io::Write;
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let mut client = std::net::TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let (server_side, _) = listener.accept().unwrap();
        let mut fds = [PollFd::new(server_side.as_raw_fd(), POLLIN)];
        assert_eq!(poll_fds(&mut fds, 10).unwrap(), 0, "no bytes yet");
        client.write_all(b"x").unwrap();
        let mut fds = [PollFd::new(server_side.as_raw_fd(), POLLIN)];
        assert_eq!(poll_fds(&mut fds, 1000).unwrap(), 1);
        assert!(fds[0].readable());
    }
}
