"""Build-time training of the runnable models on the synthetic datasets.

Plain-jax Adam + softmax cross-entropy; small models and easy synthetic
tasks converge in a couple of epochs on CPU. Training happens exactly once
(`make artifacts`) and never on the request path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M


def cross_entropy(logits, y):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def _loss_fn(params, spec, x, y):
    logits = M.forward(spec, params, x)
    return cross_entropy(logits, y)


def adam_init(params):
    zeros = lambda p: jax.tree_util.tree_map(jnp.zeros_like, p)
    return dict(m=zeros(params), v=zeros(params), t=0)


@functools.partial(jax.jit, static_argnames=("spec_name",))
def _train_step(params, opt, x, y, lr, spec_name):
    spec = M.SPECS[spec_name]()
    loss, grads = jax.value_and_grad(_loss_fn)(params, spec, x, y)
    b1, b2, eps = 0.9, 0.999, 1e-8
    t = opt["t"] + 1
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, opt["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, opt["v"], grads)
    mhat_scale = 1.0 / (1 - b1**t)
    vhat_scale = 1.0 / (1 - b2**t)
    params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
        params, m, v,
    )
    return params, dict(m=m, v=v, t=t), loss


def train(spec, x_train, y_train, epochs=4, batch=128, lr=1e-3, seed=0, log=None):
    """Train `spec` on (x_train, y_train); returns (params, loss_history)."""
    params = M.init_params(spec, seed=seed)
    opt = adam_init(params)
    n = x_train.shape[0]
    rng = np.random.default_rng(seed)
    history = []
    xs = jnp.asarray(x_train)
    ys = jnp.asarray(y_train)
    for epoch in range(epochs):
        order = rng.permutation(n)
        epoch_loss, steps = 0.0, 0
        for i in range(0, n - batch + 1, batch):
            idx = order[i:i + batch]
            params, opt, loss = _train_step(
                params, opt, xs[idx], ys[idx], lr, spec["name"]
            )
            epoch_loss += float(loss)
            steps += 1
        history.append(epoch_loss / max(steps, 1))
        if log:
            log(f"  epoch {epoch + 1}/{epochs}: loss {history[-1]:.4f}")
    return params, history


def train_autoencoder(h_samples, bottleneck, epochs=60, lr=1e-3, seed=0):
    """Train a 1-layer linear autoencoder on activation samples `h_samples`
    [N, D] -> enc [D, bottleneck], dec [bottleneck, D] (+ biases).

    This is the DeepCOD-style baseline's compressor: it trades extra
    device/server compute for a smaller uplink payload."""
    rng = np.random.default_rng(seed)
    d = h_samples.shape[1]
    params = dict(
        we=jnp.asarray(rng.normal(0, np.sqrt(1.0 / d), size=(d, bottleneck)), jnp.float32),
        be=jnp.zeros((bottleneck,), jnp.float32),
        wd=jnp.asarray(rng.normal(0, np.sqrt(1.0 / bottleneck), size=(bottleneck, d)), jnp.float32),
        bd=jnp.zeros((d,), jnp.float32),
    )

    def loss_fn(p, h):
        z = h @ p["we"] + p["be"]
        rec = z @ p["wd"] + p["bd"]
        return jnp.mean((rec - h) ** 2)

    @jax.jit
    def step(p, opt, h):
        loss, g = jax.value_and_grad(loss_fn)(p, h)
        b1, b2, eps = 0.9, 0.999, 1e-8
        t = opt["t"] + 1
        m = jax.tree_util.tree_map(lambda m_, g_: b1 * m_ + (1 - b1) * g_, opt["m"], g)
        v = jax.tree_util.tree_map(lambda v_, g_: b2 * v_ + (1 - b2) * g_ * g_, opt["v"], g)
        p = jax.tree_util.tree_map(
            lambda p_, m_, v_: p_ - lr * (m_ / (1 - b1**t)) / (jnp.sqrt(v_ / (1 - b2**t)) + eps),
            p, m, v,
        )
        return p, dict(m=m, v=v, t=t), loss

    opt = adam_init(params)
    h = jnp.asarray(h_samples)
    losses = []
    for _ in range(epochs):
        params, opt, loss = step(params, opt, h)
        losses.append(float(loss))
    return params, losses
