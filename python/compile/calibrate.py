"""Offline calibration: per-layer noise scales `s_l` and robustness `rho_l(a)`.

This is the expensive part of the paper's Algorithm 1 (lines 7-10), run once
at build time:

* ``s_l`` (Eq. 18/19): quantize source `l` (a layer's weights+bias, or a
  boundary activation) at a few bit-widths `b`, measure the injected noise
  energy on the network output, and fit `s = E_b * 4^b` (the model says
  `E_b = s * 4^{-b}`).
* ``rho_l(a)`` (Eq. 22 / Algorithm 1 line 8): inject Gaussian noise into
  source `l`, bisect the magnitude at which top-1 accuracy degrades by
  exactly `a`, and record the corresponding *output* noise energy. By
  construction a pattern with Sum psi = Sum E_l/rho_l(a) <= 1 keeps predicted
  degradation <= a, which is the constraint the Rust solver enforces.
* adversarial energy (Eq. 22's normalizer, diagnostics): mean squared
  top1-top2 logit margin distance — the smallest logit perturbation that
  flips a prediction.

Output schema matches `qpart_core::accuracy::CalibrationTable::from_json`.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from . import model as M

DEFAULT_LEVELS = (0.0025, 0.005, 0.01, 0.02, 0.05)
# Fit bits include 2: the solver's lower bound is 2 bits, and extrapolating
# the s*4^{-b} law from the 4..8 regime *underestimates* low-bit noise
# (observed as ~10% degradation on edgecnn_cifar10). Taking the max over
# the fits (upper envelope) keeps the constraint conservative everywhere.
_FIT_BITS = (2, 4, 6, 8)
_RHO_MIN = 1e-12


def quantize_array(a, bits: int):
    """Uniform asymmetric quantization (paper Eq. 9-10) of a whole tensor.
    Returns (dequantized, codes, qmin, step)."""
    a = np.asarray(a, dtype=np.float32)
    mn, mx = float(a.min()), float(a.max())
    if mn == mx:
        mn, mx = mn - 1e-6, mx + 1e-6
    step = (mx - mn) / (2**bits - 1)
    codes = np.clip(np.round((a - mn) / step), 0, 2**bits - 1).astype(np.float32)
    return (mn + codes * step).astype(np.float32), codes, np.float32(mn), np.float32(step)


def _logits(spec, params, x):
    return np.asarray(M.forward(spec, params, jnp.asarray(x)))


def _acc_from_logits(logits, y):
    return float((logits.argmax(axis=1) == y).mean())


def _out_energy(base_logits, pert_logits):
    """Mean per-sample squared-L2 output perturbation."""
    d = pert_logits - base_logits
    return float((d**2).sum(axis=1).mean())


def _quantize_layer_params(params, l, bits):
    """Copy of params with layer l (1-based) weights+bias quantized."""
    q = [dict(p) for p in params]
    wq, _, _, _ = quantize_array(np.asarray(q[l - 1]["w"]), bits)
    bq, _, _, _ = quantize_array(np.asarray(q[l - 1]["b"]), bits)
    q[l - 1] = dict(w=jnp.asarray(wq), b=jnp.asarray(bq))
    return q


def _forward_with_act_noise(spec, params, x, l, noise):
    """Forward with `noise` added to the activation at boundary l (0..L)."""
    h = jnp.asarray(x)
    if l > 0:
        h = M.forward(spec, params, h, upto=l)
    h = h + jnp.asarray(noise)
    if l == len(spec["layers"]):
        return np.asarray(h)
    return np.asarray(M.forward_from(spec, params, h, l))


def _forward_with_weight_noise(spec, params, x, l, rng, sigma):
    """Forward with N(0, sigma^2) noise on layer l's weights."""
    noisy = [dict(p) for p in params]
    w = np.asarray(noisy[l - 1]["w"])
    noisy[l - 1] = dict(
        w=jnp.asarray(w + rng.normal(0, sigma, size=w.shape).astype(np.float32)),
        b=noisy[l - 1]["b"],
    )
    return np.asarray(M.forward(spec, noisy, jnp.asarray(x)))


def measure_s_weight(spec, params, x_cal, l):
    """Fit s_l^w from actual quantization at several bit-widths."""
    base = _logits(spec, params, x_cal)
    ests = []
    for bits in _FIT_BITS:
        q = _quantize_layer_params(params, l, bits)
        e = _out_energy(base, _logits(spec, q, x_cal))
        ests.append(e * (4.0**bits))
    return max(float(np.max(ests)), _RHO_MIN)


def measure_s_activation(spec, params, x_cal, l):
    """Fit s_l^x by quantizing the boundary-l activation."""
    base = _logits(spec, params, x_cal)
    h = np.asarray(M.forward(spec, params, jnp.asarray(x_cal), upto=l)) if l > 0 \
        else np.asarray(x_cal, dtype=np.float32)
    ests = []
    for bits in _FIT_BITS:
        hq, _, _, _ = quantize_array(h, bits)
        if l == len(spec["layers"]):
            out = hq
        else:
            out = np.asarray(M.forward_from(spec, params, jnp.asarray(hq), l))
        e = _out_energy(base, out)
        ests.append(e * (4.0**bits))
    return max(float(np.max(ests)), _RHO_MIN)


def measure_rho(spec, params, x_cal, y_cal, l, levels, kind,
                iters=9, draws=2, seed=0):
    """Bisect the noise magnitude where degradation == a for each level `a`.
    Returns (rhos, base_acc). kind in {'weight', 'activation'}."""
    rng = np.random.default_rng(seed + 1000 * l + (0 if kind == "weight" else 500_000))
    base_logits = _logits(spec, params, x_cal)
    base_acc = _acc_from_logits(base_logits, y_cal)

    if kind == "weight":
        ref_scale = float(np.asarray(params[l - 1]["w"]).std()) or 1e-3
        h_shape = None
    else:
        h = np.asarray(M.forward(spec, params, jnp.asarray(x_cal), upto=l)) if l > 0 \
            else np.asarray(x_cal, dtype=np.float32)
        ref_scale = float(h.std()) or 1e-3
        h_shape = h.shape

    def probe(sigma):
        """Mean (degradation, output-noise-energy) over `draws` draws."""
        degs, energies = [], []
        for d in range(draws):
            if kind == "weight":
                out = _forward_with_weight_noise(spec, params, x_cal, l,
                                                 np.random.default_rng(rng.integers(2**31)), sigma)
            else:
                noise = np.random.default_rng(rng.integers(2**31)).normal(
                    0, sigma, size=h_shape).astype(np.float32)
                out = _forward_with_act_noise(spec, params, x_cal, l, noise)
            degs.append(base_acc - _acc_from_logits(out, y_cal))
            energies.append(_out_energy(base_logits, out))
        return float(np.mean(degs)), float(np.mean(energies))

    # Shared log-sigma sweep: probe a grid once, then interpolate rho per
    # level (cheaper than independent bisections and monotone by averaging).
    sigmas = ref_scale * np.logspace(-3.5, 1.0, iters * 2)
    degs, energies = [], []
    for s in sigmas:
        d, e = probe(float(s))
        degs.append(d)
        energies.append(e)
    degs = np.maximum.accumulate(np.asarray(degs))  # enforce monotonicity
    energies = np.asarray(energies)

    rhos = []
    for a in levels:
        if degs[-1] <= a:
            rhos.append(float(energies[-1]))  # never degrades that much: very robust
            continue
        if degs[0] >= a:
            rhos.append(max(float(energies[0]) * a / max(degs[0], 1e-9), _RHO_MIN))
            continue
        idx = int(np.searchsorted(degs, a))
        # log-interpolate energy between the bracketing probes
        d0, d1 = degs[idx - 1], degs[idx]
        e0, e1 = max(energies[idx - 1], _RHO_MIN), max(energies[idx], _RHO_MIN)
        t = 0.0 if d1 == d0 else (a - d0) / (d1 - d0)
        rho = float(np.exp(np.log(e0) * (1 - t) + np.log(e1) * t))
        rhos.append(max(rho, _RHO_MIN))
    return rhos, base_acc


def adversarial_energy(spec, params, x_cal):
    """Eq. 22 normalizer: mean squared distance to the decision boundary in
    logit space = ((z_top1 - z_top2)/sqrt(2))^2 averaged over the set."""
    logits = _logits(spec, params, x_cal)
    part = np.partition(logits, -2, axis=1)
    margin = part[:, -1] - part[:, -2]
    return float(((margin / np.sqrt(2.0)) ** 2).mean())


def calibrate(spec, params, x_cal, y_cal, levels=DEFAULT_LEVELS, seed=0, log=None):
    """Full calibration for one model; returns the calibration dict
    (schema: CalibrationTable::from_json)."""
    L = len(spec["layers"])
    levels = list(levels)
    weight = []
    for l in range(1, L + 1):
        s = measure_s_weight(spec, params, x_cal, l)
        rho, _ = measure_rho(spec, params, x_cal, y_cal, l, levels, "weight", seed=seed)
        weight.append(dict(s=s, rho=rho))
        if log:
            log(f"  weight l={l}: s={s:.4g} rho={['%.3g' % r for r in rho]}")
    activation = []
    valid = set(spec["partition_points"])
    for l in range(0, L + 1):
        if l not in valid:
            # Boundary can never be a partition point (residual-restricted
            # arch): emit a placeholder entry the solver will never query
            # (offline enumeration only visits partition_points).
            activation.append(dict(s=1e-9, rho=[1.0] * len(levels), unused=True))
            continue
        s = measure_s_activation(spec, params, x_cal, l)
        rho, _ = measure_rho(spec, params, x_cal, y_cal, l, levels, "activation", seed=seed)
        activation.append(dict(s=s, rho=rho))
        if log:
            log(f"  act    l={l}: s={s:.4g} rho={['%.3g' % r for r in rho]}")
    return dict(
        model=spec["name"],
        levels=levels,
        weight=weight,
        activation=activation,
        adversarial_energy=adversarial_energy(spec, params, x_cal),
    )
