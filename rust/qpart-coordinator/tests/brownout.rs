//! Brownout + supervision integration tests: under a storm the ladder
//! must enter AND exit (the gauge returns to 0), every degraded reply
//! must still satisfy its request's accuracy budget, and injected worker
//! panics must become error replies plus respawned workers — never a
//! wedged server. No PJRT required (synthetic bundle, host fallback).

use qpart_coordinator::brownout::{degrade_level, MAX_LEVEL};
use qpart_coordinator::client::paper_request;
use qpart_coordinator::testing::{synthetic_bundle, synthetic_upload, tiny_arch, BlockingConn};
use qpart_coordinator::{serve, FaultSpec, ServerConfig};
use qpart_core::accuracy::CalibrationTable;
use qpart_core::optimizer::{offline_quantize, OfflineConfig};
use qpart_proto::messages::{Request, Response};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Poll `f` until it returns true or `deadline` elapses.
fn wait_until<F: Fn() -> bool>(deadline: Duration, f: F) -> bool {
    let t0 = Instant::now();
    while t0.elapsed() < deadline {
        if f() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    f()
}

#[test]
fn degraded_levels_from_real_offline_tables_always_fit_the_budget() {
    // the same tables Algorithm 1 hands the live server: whatever rung
    // the ladder picks, every pattern at that level must fit the budget
    let arch = tiny_arch();
    let levels = [0.0025, 0.005, 0.01, 0.02, 0.05];
    let calib = CalibrationTable::synthetic(&arch, &levels, 1);
    let set = offline_quantize(&arch, &calib, OfflineConfig::default()).unwrap();
    for (nominal, &budget) in set.levels.iter().enumerate() {
        for rungs in 0..=MAX_LEVEL {
            let j = degrade_level(&set, nominal, budget, rungs);
            assert!(j >= nominal, "ladder must never refine below nominal");
            assert!(j < set.levels.len());
            assert!(
                j <= nominal + rungs as usize,
                "ladder overstepped its depth: {nominal} -> {j} with {rungs} rungs"
            );
            if j > nominal {
                for p in &set.patterns[j] {
                    assert!(
                        p.predicted_degradation <= budget + 1e-12,
                        "degraded level {j} breaks budget {budget}: predicted {}",
                        p.predicted_degradation
                    );
                }
            }
        }
        // zero rungs is the brownout-off fast path: always nominal
        assert_eq!(degrade_level(&set, nominal, budget, 0), nominal);
    }
}

#[test]
fn brownout_enters_under_storm_exits_after_and_degrades_only_within_budget() {
    let dir = synthetic_bundle("brownout-storm");
    let handle = serve(ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers: 2,
        host_fallback: true,
        // a 500µs queue-wait threshold the injected 5ms batch delay is
        // guaranteed to blow through while the flood runs
        brownout_wait_us: 500,
        fault_inject: Some(FaultSpec { exec_delay_ms: 5, ..FaultSpec::default() }),
        artifacts_dir: dir.to_str().unwrap().to_string(),
        ..ServerConfig::default()
    })
    .unwrap();
    let addr = handle.addr.to_string();

    let stop = Arc::new(AtomicBool::new(false));
    let budget = 0.02;
    let floods: Vec<_> = (0..6)
        .map(|_| {
            let addr = addr.clone();
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || -> (u64, u64) {
                let mut conn = BlockingConn::connect(&addr).unwrap();
                let (mut served, mut degraded) = (0u64, 0u64);
                while !stop.load(Ordering::Relaxed) {
                    match conn.call(&Request::Infer(paper_request("tinymlp", budget))) {
                        Ok(Response::Segment(r)) => {
                            served += 1;
                            if r.degraded {
                                degraded += 1;
                                // the acceptance invariant: a degraded
                                // reply still satisfies its budget
                                assert!(
                                    r.pattern.predicted_degradation <= budget + 1e-9,
                                    "degraded reply breaks budget {budget}: predicted {}",
                                    r.pattern.predicted_degradation
                                );
                            }
                        }
                        Ok(Response::Error(e)) if e.code == "overloaded" => {}
                        Ok(other) => panic!("unexpected {other:?}"),
                        Err(e) => panic!("storm client: {e}"),
                    }
                }
                (served, degraded)
            })
        })
        .collect();

    // the storm must push the ladder up...
    assert!(
        wait_until(Duration::from_secs(30), || {
            handle.snapshot().brownout_enters_total > 0
        }),
        "brownout never entered under storm (ewma never crossed 500µs?)"
    );
    // ...hold it hot briefly so requests are actually planned at depth...
    std::thread::sleep(Duration::from_millis(300));
    stop.store(true, Ordering::Relaxed);
    let mut served = 0u64;
    let mut degraded = 0u64;
    for f in floods {
        let (s, d) = f.join().expect("storm client panicked");
        served += s;
        degraded += d;
    }
    assert!(served > 0, "storm served nothing");
    println!("storm: {served} served, {degraded} degraded (all within budget)");

    // ...and once the flood stops, the controller must step all the way
    // back down: gauge to 0, with exit transitions recorded
    assert!(
        wait_until(Duration::from_secs(30), || handle.snapshot().brownout_level == 0),
        "brownout gauge stuck at {} after the storm",
        handle.snapshot().brownout_level
    );
    let snap = handle.snapshot();
    assert!(snap.brownout_enters_total > 0);
    assert!(snap.brownout_exits_total > 0, "entered but never exited");

    // calm again: a fresh request is served undegraded
    let mut conn = BlockingConn::connect(&addr).unwrap();
    match conn.call(&Request::Infer(paper_request("tinymlp", budget))).unwrap() {
        Response::Segment(r) => assert!(!r.degraded, "calm server still degrading"),
        other => panic!("unexpected {other:?}"),
    }
    handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn injected_worker_panics_become_error_replies_and_workers_respawn() {
    let dir = synthetic_bundle("panic-respawn");
    let handle = serve(ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers: 2,
        host_fallback: true,
        fault_inject: Some(FaultSpec { worker_panic: 0.5, ..FaultSpec::default() }),
        artifacts_dir: dir.to_str().unwrap().to_string(),
        ..ServerConfig::default()
    })
    .unwrap();
    let addr = handle.addr.to_string();
    let arch = tiny_arch();

    // one synchronous client rides through the worker churn: every call
    // gets an answer — a segment or a soft `internal` — never a hang or
    // a dropped connection
    let mut conn = BlockingConn::connect(&addr).unwrap();
    let (mut oks, mut internals) = (0u64, 0u64);
    for i in 0..40 {
        match conn.call(&Request::Infer(paper_request("tinymlp", 0.02))) {
            Ok(Response::Segment(r)) => {
                assert!(r.session > 0);
                oks += 1;
                // phase 2 completes on surviving sessions: the panic
                // never poisons the shared caches or the session table
                match conn.call(&Request::Activation(synthetic_upload(&r, &arch, i))) {
                    Ok(Response::Result(_)) => {}
                    Ok(Response::Error(e)) => {
                        assert_eq!(e.code, "internal", "{}", e.message);
                        internals += 1;
                    }
                    Ok(other) => panic!("unexpected {other:?}"),
                    Err(e) => panic!("connection died mid-phase-2: {e}"),
                }
            }
            Ok(Response::Error(e)) => {
                assert_eq!(e.code, "internal", "{}", e.message);
                internals += 1;
            }
            Ok(other) => panic!("unexpected {other:?}"),
            Err(e) => panic!("connection died on a panicked worker: {e}"),
        }
    }
    assert!(internals > 0, "worker-panic=0.5 never fired across 40 requests");
    assert!(oks > 0, "no request survived the worker churn");

    // the supervisor replaced every dead worker
    assert!(
        wait_until(Duration::from_secs(10), || {
            handle.snapshot().worker_restarts_total > 0
        }),
        "panics fired ({internals} internal replies) but no worker restart was recorded"
    );
    println!(
        "churn: {oks} ok, {internals} internal, {} restarts",
        handle.snapshot().worker_restarts_total
    );

    // and the pool still serves after all that
    match conn.call(&Request::Infer(paper_request("tinymlp", 0.02))) {
        Ok(Response::Segment(_)) | Ok(Response::Error(_)) => {}
        Ok(other) => panic!("unexpected {other:?}"),
        Err(e) => panic!("server wedged after restarts: {e}"),
    }
    drop(conn);
    assert!(
        wait_until(Duration::from_secs(5), || handle.snapshot().conns_open == 0),
        "conns_open stuck at {}",
        handle.snapshot().conns_open
    );
    handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn deadline_already_blown_in_queue_is_shed_with_a_soft_error() {
    let dir = synthetic_bundle("deadline-shed");
    let handle = serve(ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers: 1,
        host_fallback: true,
        // every batch waits 200ms before draining: a 1ms deadline is
        // deterministically blown in the queue
        fault_inject: Some(FaultSpec { exec_delay_ms: 200, ..FaultSpec::default() }),
        artifacts_dir: dir.to_str().unwrap().to_string(),
        ..ServerConfig::default()
    })
    .unwrap();
    let addr = handle.addr.to_string();

    // warm the pipeline so the *next* request queues behind a delayed
    // batch (the injected delay runs before the drain is inspected)
    let mut conn = BlockingConn::connect(&addr).unwrap();
    match conn.call(&Request::Infer(paper_request("tinymlp", 0.02))).unwrap() {
        Response::Segment(_) => {}
        other => panic!("unexpected {other:?}"),
    }

    let mut req = paper_request("tinymlp", 0.02);
    req.deadline_ms = Some(1);
    match conn.call(&Request::Infer(req)).unwrap() {
        Response::Error(e) => assert_eq!(e.code, "deadline_exceeded", "{}", e.message),
        other => panic!("blown deadline not shed: {other:?}"),
    }
    assert!(handle.snapshot().deadline_shed_total >= 1);

    // an undeadlined request on the same connection still completes
    match conn.call(&Request::Infer(paper_request("tinymlp", 0.02))).unwrap() {
        Response::Segment(r) => assert!(r.session > 0),
        other => panic!("unexpected {other:?}"),
    }
    handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
