//! Analytic cost models of the four compared offloading schemes
//! (paper §V, Fig. 5/7/8/9/10).
//!
//! Each scheme, at a given partition point `p`, determines (a) the
//! communication payload `Z` and (b) the device/server MAC counts; the
//! Eq. 17 objective then follows from `qpart_core::cost`. Accuracy of the
//! schemes is *measured* (qpart-runtime baselines, Table III) — this module
//! is the analytic time/energy/cost side.

use qpart_core::cost::{CostBreakdown, CostModel};
use qpart_core::model::ModelSpec;
use qpart_core::quant::{PatternSet, QuantPattern};
use qpart_core::{Error, Result};

/// The compared offloading schemes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Scheme {
    /// The paper's system: layer-wise quantization via the offline table.
    Qpart,
    /// Ship the f32 segment + f32 activation (paper's "No Optimization").
    NoOpt,
    /// 2-step structured pruning of the device segment (Shi et al.-style):
    /// prune `ratio` of each device layer's neurons.
    Pruning { ratio: f64 },
    /// DeepCOD-style autoencoder on the boundary activation:
    /// bottleneck = activation / `compress` (f32 model segment).
    Autoencoder { compress: f64 },
}

impl Scheme {
    pub fn name(&self) -> &'static str {
        match self {
            Scheme::Qpart => "QPART",
            Scheme::NoOpt => "No Optimization",
            Scheme::Pruning { .. } => "Model Pruning",
            Scheme::Autoencoder { .. } => "Auto-Encoder",
        }
    }
}

/// Cost evaluation of one scheme at one partition point.
#[derive(Debug, Clone)]
pub struct SchemeCost {
    pub scheme: &'static str,
    pub partition: usize,
    /// Communication payload (bits): downlink weights + uplink activation.
    pub payload_bits: u64,
    pub device_macs: u64,
    pub server_macs: u64,
    pub breakdown: CostBreakdown,
}

/// Evaluate `scheme` at partition `p` under `cost`.
///
/// For QPART, `patterns` supplies the offline bit-width table and
/// `level_idx` the accuracy level (the other schemes ignore both).
pub fn scheme_cost(
    scheme: Scheme,
    model: &ModelSpec,
    cost: &CostModel,
    p: usize,
    patterns: Option<&PatternSet>,
    level_idx: usize,
) -> Result<SchemeCost> {
    if p > model.num_layers() {
        return Err(Error::InvalidArg(format!("partition {p} > L")));
    }
    let (payload_bits, device_macs, server_macs) = match scheme {
        Scheme::Qpart => {
            let set = patterns
                .ok_or_else(|| Error::InvalidArg("QPART needs a pattern set".into()))?;
            let pat = set
                .get(qpart_core::quant::PatternKey { level_idx, partition: p })
                .ok_or_else(|| Error::NotFound(format!("pattern (k={level_idx}, p={p})")))?;
            (pat.payload_bits(model), model.device_macs(p), model.server_macs(p))
        }
        Scheme::NoOpt => {
            let pat32 = QuantPattern {
                partition: p,
                weight_bits: vec![32; p],
                activation_bits: 32,
                accuracy_level: 0.0,
                predicted_degradation: 0.0,
            };
            (pat32.payload_bits(model), model.device_macs(p), model.server_macs(p))
        }
        Scheme::Pruning { ratio } => {
            if !(0.0..1.0).contains(&ratio) {
                return Err(Error::InvalidArg(format!("prune ratio {ratio}")));
            }
            let kept = 1.0 - ratio;
            // pruned device layers: fewer weights to ship & fewer MACs;
            // the boundary activation shrinks too (pruned neurons emit 0).
            let w_bits = (model.segment_weight_bits_f32(p) as f64 * kept) as u64;
            let a_bits = (32.0 * model.activation_elems(p) as f64 * kept) as u64;
            let d_macs = (model.device_macs(p) as f64 * kept) as u64;
            (w_bits + a_bits, d_macs, model.server_macs(p))
        }
        Scheme::Autoencoder { compress } => {
            if compress < 1.0 {
                return Err(Error::InvalidArg(format!("AE compress {compress}")));
            }
            let act = model.activation_elems(p) as f64;
            let bottleneck = (act / compress).ceil().max(1.0);
            // encoder (device) and decoder (server) are 1-layer linear maps
            let enc_macs = (act * bottleneck) as u64;
            let dec_macs = enc_macs;
            let enc_params = (act * bottleneck + bottleneck) as u64;
            let w_bits = model.segment_weight_bits_f32(p) + 32 * enc_params;
            let a_bits = 32 * bottleneck as u64;
            (
                w_bits + a_bits,
                model.device_macs(p) + enc_macs,
                model.server_macs(p) + dec_macs,
            )
        }
    };
    // Evaluate Eq. 17 with explicit MAC overrides (AE/pruning change MACs).
    let t_local = cost.device.compute_time_s(device_macs);
    let t_server = cost.server.compute_time_s(server_macs);
    let t_tran = cost.channel.tx_latency_s(payload_bits);
    let e_local = cost.device.compute_energy_j(device_macs);
    let e_tran = cost.channel.tx_energy_j(payload_bits);
    let server_cost = cost.server.compute_cost(server_macs);
    let objective = cost.weights.omega * (t_local + t_server + t_tran)
        + cost.weights.tau * (e_local + e_tran)
        + cost.weights.eta * server_cost;
    Ok(SchemeCost {
        scheme: scheme.name(),
        partition: p,
        payload_bits,
        device_macs,
        server_macs,
        breakdown: CostBreakdown {
            t_local_s: t_local,
            t_server_s: t_server,
            t_tran_s: t_tran,
            e_local_j: e_local,
            e_tran_j: e_tran,
            server_cost,
            objective,
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use qpart_core::accuracy::CalibrationTable;
    use qpart_core::model::mlp6;
    use qpart_core::optimizer::{offline_quantize, OfflineConfig};

    const LEVELS: [f64; 5] = [0.0025, 0.005, 0.01, 0.02, 0.05];

    fn setup() -> (ModelSpec, PatternSet, CostModel) {
        let m = mlp6();
        let c = CalibrationTable::synthetic(&m, &LEVELS, 41);
        let set = offline_quantize(&m, &c, OfflineConfig::default()).unwrap();
        (m, set, CostModel::paper_default())
    }

    #[test]
    fn qpart_beats_noopt_everywhere() {
        // Fig. 7's headline shape: QPART's objective ≤ NoOpt at every p.
        let (m, set, cost) = setup();
        for p in 0..=m.num_layers() {
            let q = scheme_cost(Scheme::Qpart, &m, &cost, p, Some(&set), 2).unwrap();
            let n = scheme_cost(Scheme::NoOpt, &m, &cost, p, None, 0).unwrap();
            assert!(
                q.breakdown.objective <= n.breakdown.objective,
                "p={p}: qpart {} vs noopt {}",
                q.breakdown.objective,
                n.breakdown.objective
            );
            assert!(q.payload_bits <= n.payload_bits);
        }
    }

    #[test]
    fn ae_pays_compute_overhead() {
        // Fig. 8/9's shape: AE adds enc/dec MACs on both sides.
        let (m, _, cost) = setup();
        let ae = scheme_cost(Scheme::Autoencoder { compress: 8.0 }, &m, &cost, 3, None, 0)
            .unwrap();
        let no = scheme_cost(Scheme::NoOpt, &m, &cost, 3, None, 0).unwrap();
        assert!(ae.device_macs > no.device_macs);
        assert!(ae.server_macs > no.server_macs);
        // ...but compresses the uplink activation
        assert!(ae.payload_bits > no.payload_bits - 32 * m.activation_elems(3));
    }

    #[test]
    fn pruning_scales_by_kept_fraction() {
        let (m, _, cost) = setup();
        let pr = scheme_cost(Scheme::Pruning { ratio: 0.5 }, &m, &cost, 4, None, 0).unwrap();
        let no = scheme_cost(Scheme::NoOpt, &m, &cost, 4, None, 0).unwrap();
        let ratio = pr.payload_bits as f64 / no.payload_bits as f64;
        assert!((0.45..0.55).contains(&ratio), "payload ratio {ratio}");
        assert!(pr.device_macs < no.device_macs);
    }

    #[test]
    fn server_cost_monotone_decreasing_in_p() {
        // Fig. 5 third panel, for every scheme.
        let (m, set, cost) = setup();
        for scheme in [
            Scheme::Qpart,
            Scheme::NoOpt,
            Scheme::Pruning { ratio: 0.3 },
            Scheme::Autoencoder { compress: 8.0 },
        ] {
            let mut prev = f64::INFINITY;
            for p in 0..=m.num_layers() {
                let c = scheme_cost(scheme, &m, &cost, p, Some(&set), 2).unwrap();
                // AE adds a p-dependent decoder; allow tiny non-monotonicity
                assert!(
                    c.breakdown.server_cost <= prev * 1.05,
                    "{}: p={p}",
                    scheme.name()
                );
                prev = c.breakdown.server_cost;
            }
        }
    }

    #[test]
    fn invalid_inputs_rejected() {
        let (m, set, cost) = setup();
        assert!(scheme_cost(Scheme::Qpart, &m, &cost, 99, Some(&set), 0).is_err());
        assert!(scheme_cost(Scheme::Qpart, &m, &cost, 1, None, 0).is_err());
        assert!(scheme_cost(Scheme::Pruning { ratio: 1.5 }, &m, &cost, 1, None, 0).is_err());
        assert!(
            scheme_cost(Scheme::Autoencoder { compress: 0.5 }, &m, &cost, 1, None, 0).is_err()
        );
    }
}
