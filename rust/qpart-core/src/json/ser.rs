//! JSON serializer: compact or pretty, deterministic (preserves object
//! insertion order), shortest-round-trip float formatting.

use super::Value;

/// Append `v` to `out`. `indent = Some(n)` pretty-prints with `n` spaces.
pub(super) fn write_value(v: &Value, out: &mut String, indent: Option<usize>, level: usize) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(true) => out.push_str("true"),
        Value::Bool(false) => out.push_str("false"),
        Value::Num(n) => write_num(*n, out),
        Value::Str(s) => write_str(s, out),
        Value::Arr(items) => {
            if items.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, level + 1);
                write_value(item, out, indent, level + 1);
            }
            newline_indent(out, indent, level);
            out.push(']');
        }
        Value::Obj(fields) => {
            if fields.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, val)) in fields.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, level + 1);
                write_str(k, out);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_value(val, out, indent, level + 1);
            }
            newline_indent(out, indent, level);
            out.push('}');
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, level: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * level {
            out.push(' ');
        }
    }
}

fn write_num(n: f64, out: &mut String) {
    if !n.is_finite() {
        // JSON has no NaN/Inf; emit null like most tolerant encoders.
        out.push_str("null");
        return;
    }
    if n == n.trunc() && n.abs() < 1e15 {
        // Integral values print without a fractional part.
        out.push_str(&format!("{}", n as i64));
    } else {
        // `{}` on f64 is Rust's shortest round-trip formatting.
        out.push_str(&format!("{n}"));
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            '\u{0008}' => out.push_str("\\b"),
            '\u{000C}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::super::{parse, Value};

    #[test]
    fn roundtrip_compact() {
        let src = r#"{"a":[1,2.5,-3],"b":{"c":"x\ny","d":[true,false,null]},"e":1e300}"#;
        let v = parse(src).unwrap();
        let out = v.to_string_compact();
        assert_eq!(parse(&out).unwrap(), v);
    }

    #[test]
    fn integral_floats_compact() {
        assert_eq!(Value::Num(3.0).to_string_compact(), "3");
        assert_eq!(Value::Num(-0.5).to_string_compact(), "-0.5");
    }

    #[test]
    fn pretty_parses_back() {
        let v = Value::obj([
            ("x", Value::num_arr(&[1.0, 2.0])),
            ("y", Value::obj([("z", Value::Null)])),
        ]);
        let pretty = v.to_string_pretty();
        assert!(pretty.contains('\n'));
        assert_eq!(parse(&pretty).unwrap(), v);
    }

    #[test]
    fn control_chars_escaped() {
        let v = Value::Str("\u{0001}tab\there".into());
        let s = v.to_string_compact();
        assert!(s.contains("\\u0001"));
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn nonfinite_to_null() {
        assert_eq!(Value::Num(f64::NAN).to_string_compact(), "null");
        assert_eq!(Value::Num(f64::INFINITY).to_string_compact(), "null");
    }
}
