"""Pure-jnp oracles for the Pallas kernels.

Every Pallas kernel in this package has a reference implementation here;
`python/tests/test_kernels.py` sweeps shapes/dtypes with hypothesis and
asserts allclose between kernel and oracle. The oracles are also what the
L2 model uses when `use_pallas=False` (debugging path).
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def dequant(codes, qmin, step):
    """Uniform asymmetric dequantization: w = qmin + codes * step."""
    return qmin + codes * step


def qlinear_ref(x, codes, qmin, step, bias, relu: bool):
    """Reference for the fused dequantize->matmul->bias->ReLU kernel.

    x:     [B, D] float32
    codes: [D, G] float32 (integer-valued quantization grid indices)
    qmin:  [1, 1] float32 (grid minimum mu)
    step:  [1, 1] float32 (grid step delta)
    bias:  [1, G] float32
    """
    w = dequant(codes, qmin[0, 0], step[0, 0])
    y = x @ w + bias
    return jnp.maximum(y, 0.0) if relu else y


def linear_ref(x, w, bias, relu: bool):
    """Full-precision linear layer."""
    y = x @ w + bias
    return jnp.maximum(y, 0.0) if relu else y


def im2col(x, k: int, stride: int):
    """Extract conv patches: x [B, C, H, W] -> [B*H'*W', C*k*k] ('SAME' pad).

    Column order is (C, kh, kw), matching a weight layout of
    [C_in, k, k, C_out] flattened to [C_in*k*k, C_out].
    """
    b, c, h, w = x.shape
    patches = lax.conv_general_dilated_patches(
        x,
        filter_shape=(k, k),
        window_strides=(stride, stride),
        padding="SAME",
    )  # [B, C*k*k, H', W']
    _, ckk, hp, wp = patches.shape
    cols = patches.transpose(0, 2, 3, 1).reshape(b * hp * wp, ckk)
    return cols, (b, hp, wp)


def qconv_ref(x, codes, qmin, step, bias, relu: bool, k: int, stride: int):
    """Reference quantized conv: im2col + qlinear.

    x:     [B, C_in, H, W]
    codes: [C_in*k*k, C_out] float32 grid indices
    bias:  [1, C_out]
    returns [B, C_out, H', W'].
    """
    cols, (b, hp, wp) = im2col(x, k, stride)
    y = qlinear_ref(cols, codes, qmin, step, bias, relu)  # [B*H'*W', C_out]
    c_out = y.shape[1]
    return y.reshape(b, hp, wp, c_out).transpose(0, 3, 1, 2)


def conv_ref(x, w, bias, relu: bool, stride: int):
    """Full-precision conv via lax.conv. w: [C_in, k, k, C_out]."""
    c_in, k, _, c_out = w.shape
    wt = w.transpose(3, 0, 1, 2)  # OIHW
    y = lax.conv_general_dilated(
        x,
        wt,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    y = y + bias.reshape(1, c_out, 1, 1)
    return jnp.maximum(y, 0.0) if relu else y
