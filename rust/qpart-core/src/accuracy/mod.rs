//! Quantization-noise / accuracy-degradation model (paper Eq. 18–22, after
//! Zhou et al., *Adaptive Quantization for Deep Neural Network*, AAAI'18).
//!
//! The model: quantizing layer `l`'s weights at `b_l` bits injects noise of
//! energy `‖σ_l^w‖² = s_l · 4^{-b_l}` into the network output (Eq. 18);
//! likewise `s_x · 4^{-b_x}` for the boundary activation (Eq. 19). Each
//! layer has a *robustness* `ρ_l(a)` — the output-noise energy at which the
//! model's accuracy degrades by exactly `a` (Eq. 22, measured offline by
//! noise injection). The degradation measure is `ψ_l = ‖σ_l‖² / ρ_l(a)`
//! (Eq. 20–21); ψ is additive across layers, so the accuracy constraint of
//! the joint problem (Eq. 23) is `Σ ψ ≤ 1` — at most the noise budget that
//! produces degradation `a`.
//!
//! `s_l` and `ρ_l(a)` come from the build-time Python calibration pass
//! (`python/compile/calibrate.py` → `artifacts/calibration.json`); for
//! descriptor-only experiments [`CalibrationTable::synthetic`] generates a
//! deterministic plausible table.

mod calibration;

pub use calibration::CalibrationTable;

/// Noise energy injected by quantizing at `bits` with scale `s` (Eq. 18–19):
/// `‖σ‖² = s · e^{−ln4·b} = s · 4^{−b}`.
pub fn noise_energy(s: f64, bits: f64) -> f64 {
    s * (-std::f64::consts::LN_2 * 2.0 * bits).exp()
}

/// Degradation measure ψ (Eq. 20–21): `ψ = ‖σ‖² / ρ`.
pub fn psi(s: f64, bits: f64, rho: f64) -> f64 {
    noise_energy(s, bits) / rho
}

/// Bits required for a single source to stay within a ψ budget:
/// smallest `b` with `s·4^{−b}/ρ ≤ budget`.
pub fn bits_for_psi_budget(s: f64, rho: f64, budget: f64) -> f64 {
    if budget <= 0.0 || rho <= 0.0 || s <= 0.0 {
        return f64::INFINITY;
    }
    // s·4^{-b} = budget·ρ  ⇒  b = log4(s / (budget·ρ))
    (s / (budget * rho)).ln() / (std::f64::consts::LN_2 * 2.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::assert_close;

    #[test]
    fn noise_energy_quarters_per_bit() {
        // Eq. 18: one extra bit → 4× less noise energy.
        let e8 = noise_energy(3.0, 8.0);
        let e9 = noise_energy(3.0, 9.0);
        assert_close(e8 / e9, 4.0, 1e-9, 1e-12);
    }

    #[test]
    fn psi_linear_in_inverse_rho() {
        assert_close(psi(2.0, 4.0, 0.5), 2.0 * psi(2.0, 4.0, 1.0), 1e-15, 1e-12);
    }

    #[test]
    fn bits_budget_inverts_psi() {
        let (s, rho, budget) = (7.3, 0.21, 0.05);
        let b = bits_for_psi_budget(s, rho, budget);
        assert_close(psi(s, b, rho), budget, 1e-12, 1e-9);
    }

    #[test]
    fn degenerate_budgets() {
        assert!(bits_for_psi_budget(1.0, 1.0, 0.0).is_infinite());
        assert!(bits_for_psi_budget(0.0, 1.0, 0.1).is_infinite());
    }
}
