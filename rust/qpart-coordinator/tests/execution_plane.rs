//! Batch-aware execution-plane tests — no PJRT required (synthetic
//! bundle + host reference kernels).
//!
//! Covers the phase-2 half of the dataplane end to end: coalesced
//! same-key activation uploads executing as ⌈N/EVAL_BATCH⌉ batched
//! server-segment runs (read back through the batch-occupancy metrics),
//! the eval-batch ladder (chunks pad to the tightest `[1, 8, 32]` rung,
//! with the padded rows metered), batched-vs-sequential numerical
//! equivalence at the ladder's boundary row counts, the Algorithm-2
//! decision cache's identity contract, the binary uplink frame over TCP
//! (negotiated, refused when not negotiated, byte-identical to the JSON
//! path), the pool-shared compile cache's once-per-key contract, and
//! `--warm-cache` startup warming.

use qpart_coordinator::client::paper_request;
use qpart_coordinator::sched::{EncodedReplyCache, Job, WireReply};
use qpart_coordinator::testing::{synthetic_bundle, synthetic_upload, tiny_arch, BlockingConn};
use qpart_coordinator::{
    serve, MetricsHub, ServerConfig, Service, ServiceOptions, SharedSessionTable, WarmMode,
};
use qpart_core::channel::Channel;
use qpart_core::cost::{CostModel, DeviceProfile, ServerProfile, TradeoffWeights};
use qpart_core::optimizer::{offline_quantize, serve_request, OfflineConfig, RequestParams};
use qpart_proto::messages::{HelloRequest, InferReply, Request, Response};
use qpart_runtime::{Bundle, EVAL_BATCH};
use std::sync::mpsc::sync_channel;
use std::sync::{Arc, Barrier};
use std::time::Duration;

/// A service over the synthetic bundle with host-kernel phase 2.
fn host_service(dir: &std::path::Path, hub: &Arc<MetricsHub>) -> Service {
    let bundle = Arc::new(Bundle::load(dir).unwrap());
    let sessions = Arc::new(SharedSessionTable::new(256, 2));
    let cache = Arc::new(EncodedReplyCache::new(64 << 20));
    Service::with_options(
        bundle,
        Arc::clone(hub),
        sessions,
        cache,
        ServiceOptions { host_fallback: true, ..ServiceOptions::default() },
    )
    .unwrap()
}

/// Open one phase-1 session (same key for a fixed budget).
fn open_session(svc: &mut Service, budget: f64) -> InferReply {
    match svc.handle(Request::Infer(paper_request("tinymlp", budget))) {
        Response::Segment(r) => r,
        other => panic!("unexpected {other:?}"),
    }
}

/// The coalescing contract for phase 2, deterministically: one batch of
/// N same-key uploads executes as ⌈N/EVAL_BATCH⌉ server-segment runs —
/// not N — and every device still gets its own correct result.
#[test]
fn batched_uploads_execute_in_eval_batch_chunks() {
    let dir = synthetic_bundle("ep-batch");
    let hub = Arc::new(MetricsHub::new());
    let mut svc = host_service(&dir, &hub);
    let arch = tiny_arch();

    let n = EVAL_BATCH + 8; // 40 rows → 2 executions (32 + 8)
    let replies: Vec<InferReply> = (0..n).map(|_| open_session(&mut svc, 0.02)).collect();

    let mut jobs = Vec::with_capacity(n);
    let mut rxs = Vec::with_capacity(n);
    for (i, r) in replies.iter().enumerate() {
        let (tx, rx) = sync_channel(1);
        jobs.push(Job::new(Request::Activation(synthetic_upload(r, &arch, i as u64)), tx));
        rxs.push((r.session, rx));
    }
    let before = hub.snapshot();
    svc.handle_batch(jobs);

    for (sid, rx) in rxs {
        match rx.recv().unwrap().0 {
            WireReply::Msg(Response::Result(res)) => {
                assert_eq!(res.session, sid);
                assert_eq!(res.logits.len(), 10, "tinymlp has 10 classes");
            }
            other => panic!("session {sid}: unexpected {other:?}"),
        }
    }

    let snap = hub.snapshot();
    assert_eq!(snap.phase2_rows_total - before.phase2_rows_total, n as u64);
    assert_eq!(
        snap.phase2_execs_total - before.phase2_execs_total,
        ((n + EVAL_BATCH - 1) / EVAL_BATCH) as u64,
        "N same-key uploads must run as ceil(N/EVAL_BATCH) executions"
    );
    assert_eq!(snap.errors_total, 0);
    assert!(snap.batch_occupancy_mean() > 1.0, "occupancy must reflect stacking");

    // the shared compile cache built each key at most once
    let cc = svc.compile_cache();
    assert!(cc.compilations() >= 1, "the phase-2 plan was built");
    assert_eq!(cc.max_compiles_per_key(), 1, "{:?}", cc.compile_counts());
    let _ = std::fs::remove_dir_all(&dir);
}

/// The eval-batch ladder contract at every boundary row count: a chunk
/// of N rows executes at the tightest `[1, 8, 32]` rung (availability is
/// moot under host kernels), the padded-rows metric records exactly the
/// rung's slack — 0 for a single-row upload — and batched results stay
/// bit-identical to sequential ones.
#[test]
fn ladder_pads_to_tightest_rung_at_boundary_counts() {
    // (rows, expected executions, expected padded rows):
    // 1→rung 1 (no padding!), 7→rung 8 (+1), 8→rung 8, 9→rung 32 (+23),
    // 32→rung 32, 33→32+1, 40→32+8 (chunking is per-EVAL_BATCH)
    let cases: [(usize, u64, u64); 7] =
        [(1, 1, 0), (7, 1, 1), (8, 1, 0), (9, 1, 23), (32, 1, 0), (33, 2, 0), (40, 2, 0)];
    for &(n, execs, padded) in &cases {
        let dir = synthetic_bundle(&format!("ep-ladder-{n}"));
        let hub_batched = Arc::new(MetricsHub::new());
        let hub_seq = Arc::new(MetricsHub::new());
        let mut batched = host_service(&dir, &hub_batched);
        let mut sequential = host_service(&dir, &hub_seq);
        let arch = tiny_arch();

        let replies_a: Vec<InferReply> =
            (0..n).map(|_| open_session(&mut batched, 0.02)).collect();
        let replies_b: Vec<InferReply> =
            (0..n).map(|_| open_session(&mut sequential, 0.02)).collect();

        let mut jobs = Vec::with_capacity(n);
        let mut rxs = Vec::with_capacity(n);
        for (i, r) in replies_a.iter().enumerate() {
            let (tx, rx) = sync_channel(1);
            jobs.push(Job::new(Request::Activation(synthetic_upload(r, &arch, i as u64)), tx));
            rxs.push(rx);
        }
        batched.handle_batch(jobs);
        let batched_logits: Vec<Vec<f64>> = rxs
            .into_iter()
            .map(|rx| match rx.recv().unwrap().0 {
                WireReply::Msg(Response::Result(res)) => res.logits,
                other => panic!("n={n}: unexpected {other:?}"),
            })
            .collect();

        // ladder equivalence: same rows, one at a time, same logits
        for (i, r) in replies_b.iter().enumerate() {
            match sequential.handle(Request::Activation(synthetic_upload(r, &arch, i as u64))) {
                Response::Result(res) => assert_eq!(
                    res.logits, batched_logits[i],
                    "n={n} row {i}: ladder-batched and sequential phase 2 must agree exactly"
                ),
                other => panic!("n={n} row {i}: unexpected {other:?}"),
            }
        }

        let snap = hub_batched.snapshot();
        assert_eq!(snap.phase2_rows_total, n as u64, "n={n}");
        assert_eq!(snap.phase2_execs_total, execs, "n={n}");
        assert_eq!(snap.phase2_padded_rows_total, padded, "n={n}");
        if n == 1 {
            assert_eq!(snap.phase2_padded_rows_total, 0, "single row runs at rung 1, unpadded");
        }
        // sequential rows each run at rung 1: never any padding
        let seq = hub_seq.snapshot();
        assert_eq!(seq.phase2_execs_total, n as u64, "n={n}");
        assert_eq!(seq.phase2_padded_rows_total, 0, "n={n}: batch-1 rows pad nothing");
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// The decision cache's identity contract: a repeat profile is a cache
/// hit, and the memoized decision (pattern AND objective) is exactly
/// what a fresh Algorithm-2 run over the same inputs produces.
#[test]
fn decision_cache_hits_return_identical_decisions() {
    let dir = synthetic_bundle("ep-decision");
    let hub = Arc::new(MetricsHub::new());
    let mut svc = host_service(&dir, &hub);

    let first = open_session(&mut svc, 0.02);
    let before = hub.snapshot();
    assert!(before.decision_misses >= 1, "first profile plans");
    let second = open_session(&mut svc, 0.02);
    let after = hub.snapshot();
    assert_eq!(after.decision_hits, before.decision_hits + 1, "repeat profile hits");
    assert_eq!(second.pattern, first.pattern, "hit serves the same decision");

    // fresh Algorithm 2 over exactly the inputs the service used: the
    // bundle's calibration through Algorithm 1, the request's device /
    // channel profile, the server-side paper defaults
    let bundle = Bundle::load(&dir).unwrap();
    let arch = bundle.arch("tinymlp").unwrap().clone();
    let calib = bundle.calibration("tinymlp").unwrap();
    let set = offline_quantize(&arch, &calib, OfflineConfig::default()).unwrap();
    let r = paper_request("tinymlp", 0.02);
    let cost = CostModel {
        device: DeviceProfile {
            clock_hz: r.clock_hz,
            cycles_per_mac: r.cycles_per_mac,
            kappa: r.kappa,
            memory_bits: r.memory_bits,
        },
        server: ServerProfile::paper_default(),
        channel: Channel::fixed(r.channel_capacity_bps, r.tx_power_w),
        weights: TradeoffWeights::paper_default(),
    };
    let fresh =
        serve_request(&arch, &set, &RequestParams { cost, accuracy_budget: 0.02 }).unwrap();
    assert_eq!(second.pattern.partition, fresh.pattern.partition);
    assert_eq!(second.pattern.weight_bits, fresh.pattern.weight_bits);
    assert_eq!(second.pattern.activation_bits, fresh.pattern.activation_bits);
    assert_eq!(second.pattern.accuracy_level, fresh.pattern.accuracy_level);
    assert_eq!(
        second.pattern.objective, fresh.cost.objective,
        "cached objective is bit-identical to a fresh serve_request"
    );

    // a different device class is a different bucket → plans again
    let mut other = paper_request("tinymlp", 0.02);
    other.channel_capacity_bps *= 4.0;
    match svc.handle(Request::Infer(other)) {
        Response::Segment(_) => {}
        other => panic!("unexpected {other:?}"),
    }
    let end = hub.snapshot();
    assert_eq!(end.decision_misses, after.decision_misses + 1, "new profile misses");

    // the stats document surfaces the decision_cache section
    match svc.handle(Request::Stats) {
        Response::Stats(v) => {
            let dc = v.req("decision_cache").unwrap();
            assert!(dc.req_f64("hits").unwrap() >= 1.0);
            assert!(dc.req_f64("entries").unwrap() >= 2.0);
        }
        other => panic!("unexpected {other:?}"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Batched and sequential phase 2 must be numerically identical: the
/// same activation rows produce bit-identical logits whether they run
/// one-at-a-time or stacked into a padded batch.
#[test]
fn batched_and_sequential_phase2_agree() {
    let dir = synthetic_bundle("ep-equiv");
    let hub_a = Arc::new(MetricsHub::new());
    let hub_b = Arc::new(MetricsHub::new());
    let mut batched = host_service(&dir, &hub_a);
    let mut sequential = host_service(&dir, &hub_b);
    let arch = tiny_arch();

    let n = 7usize;
    // same seeds → identical activation tensors on both services
    let replies_a: Vec<InferReply> = (0..n).map(|_| open_session(&mut batched, 0.02)).collect();
    let replies_b: Vec<InferReply> =
        (0..n).map(|_| open_session(&mut sequential, 0.02)).collect();

    // batched: all uploads in one handle_batch
    let mut jobs = Vec::new();
    let mut rxs = Vec::new();
    for (i, r) in replies_a.iter().enumerate() {
        let (tx, rx) = sync_channel(1);
        jobs.push(Job::new(Request::Activation(synthetic_upload(r, &arch, i as u64)), tx));
        rxs.push(rx);
    }
    batched.handle_batch(jobs);
    let batched_logits: Vec<Vec<f64>> = rxs
        .into_iter()
        .map(|rx| match rx.recv().unwrap().0 {
            WireReply::Msg(Response::Result(res)) => res.logits,
            other => panic!("unexpected {other:?}"),
        })
        .collect();

    // sequential: one handle() per upload
    for (i, r) in replies_b.iter().enumerate() {
        let resp =
            sequential.handle(Request::Activation(synthetic_upload(r, &arch, i as u64)));
        match resp {
            Response::Result(res) => {
                assert_eq!(
                    res.logits, batched_logits[i],
                    "row {i}: batched and sequential phase 2 must agree exactly"
                );
            }
            other => panic!("row {i}: unexpected {other:?}"),
        }
    }
    assert_eq!(hub_a.snapshot().phase2_execs_total, 1, "7 rows stack into one run");
    assert_eq!(hub_b.snapshot().phase2_execs_total, n as u64);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Binary uplink over TCP: a granted hello lets the device ship its
/// activation as a binary request frame; the result matches the JSON
/// control, and an un-negotiated binary frame is refused.
#[test]
fn binary_uplink_negotiated_and_byte_identical_to_json() {
    let dir = synthetic_bundle("ep-binuplink");
    let handle = serve(ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers: 2,
        host_fallback: true,
        artifacts_dir: dir.to_str().unwrap().to_string(),
        ..ServerConfig::default()
    })
    .unwrap();
    let addr = handle.addr.to_string();
    let arch = tiny_arch();

    // binary session
    let mut bin_conn = BlockingConn::connect(&addr).unwrap();
    let hello = Request::Hello(HelloRequest { binary_frames: true, ..HelloRequest::default() });
    match bin_conn.call(&hello).unwrap() {
        Response::Hello(h) => assert!(h.binary_frames),
        other => panic!("unexpected {other:?}"),
    }
    let bin_reply = match bin_conn.call(&Request::Infer(paper_request("tinymlp", 0.02))).unwrap()
    {
        Response::Segment(r) => r,
        other => panic!("unexpected {other:?}"),
    };
    let bin_upload = synthetic_upload(&bin_reply, &arch, 7);
    let bin_result = match bin_conn.call_binary_upload(&bin_upload).unwrap() {
        Response::Result(r) => r,
        other => panic!("unexpected {other:?}"),
    };

    // JSON control: identical activation values, different session
    let mut json_conn = BlockingConn::connect(&addr).unwrap();
    let json_reply =
        match json_conn.call(&Request::Infer(paper_request("tinymlp", 0.02))).unwrap() {
            Response::Segment(r) => r,
            other => panic!("unexpected {other:?}"),
        };
    assert_eq!(json_reply.pattern, bin_reply.pattern, "same key → same pattern");
    let json_upload = synthetic_upload(&json_reply, &arch, 7);
    assert_eq!(
        json_upload.packed, bin_upload.packed,
        "same seed → byte-identical packed payload on both framings"
    );
    let json_result = match json_conn.call(&Request::Activation(json_upload)).unwrap() {
        Response::Result(r) => r,
        other => panic!("unexpected {other:?}"),
    };
    assert_eq!(json_result.prediction, bin_result.prediction);
    assert_eq!(json_result.logits, bin_result.logits, "framings agree bit-for-bit");

    // a binary request frame before hello is refused, connection survives
    let mut cold_conn = BlockingConn::connect(&addr).unwrap();
    let cold_reply =
        match cold_conn.call(&Request::Infer(paper_request("tinymlp", 0.02))).unwrap() {
            Response::Segment(r) => r,
            other => panic!("unexpected {other:?}"),
        };
    let cold_upload = synthetic_upload(&cold_reply, &arch, 1);
    match cold_conn.call_binary_upload(&cold_upload).unwrap() {
        Response::Error(e) => assert_eq!(e.code, "bad_frame", "{}", e.message),
        other => panic!("unexpected {other:?}"),
    }
    // ...and the same upload over JSON still works afterwards
    match cold_conn.call(&Request::Activation(cold_upload)).unwrap() {
        Response::Result(_) => {}
        other => panic!("unexpected {other:?}"),
    }
    handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Pool-level contract over TCP: concurrent same-key uploads across a
/// multi-worker server coalesce into fewer executions than rows, and the
/// shared compile cache never builds a key twice across workers.
#[test]
fn pool_coalesces_uploads_and_compiles_once_across_workers() {
    let dir = synthetic_bundle("ep-pool");
    let handle = serve(ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers: 4,
        batch_window: Duration::from_millis(25),
        host_fallback: true,
        artifacts_dir: dir.to_str().unwrap().to_string(),
        ..ServerConfig::default()
    })
    .unwrap();
    let addr = handle.addr.to_string();
    let arch = tiny_arch();

    let clients = 12usize;
    let barrier = Arc::new(Barrier::new(clients));
    let joins: Vec<_> = (0..clients)
        .map(|c| {
            let addr = addr.clone();
            let arch = arch.clone();
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                let mut conn = BlockingConn::connect(&addr).unwrap();
                let reply =
                    match conn.call(&Request::Infer(paper_request("tinymlp", 0.02))).unwrap() {
                        Response::Segment(r) => r,
                        other => panic!("client {c}: unexpected {other:?}"),
                    };
                let upload = synthetic_upload(&reply, &arch, c as u64);
                barrier.wait(); // uploads land together → coalescible
                match conn.call(&Request::Activation(upload)).unwrap() {
                    Response::Result(r) => r.prediction,
                    other => panic!("client {c}: unexpected {other:?}"),
                }
            })
        })
        .collect();
    for j in joins {
        let _ = j.join().unwrap();
    }

    let snap = handle.snapshot();
    assert_eq!(snap.phase2_rows_total, clients as u64, "every upload executed");
    assert!(snap.phase2_execs_total >= 1);
    assert!(
        snap.phase2_execs_total <= clients as u64,
        "executions never exceed rows: {snap:?}"
    );
    assert_eq!(snap.errors_total, 0);

    // once-per-key across ALL workers — the shared-compile-cache contract
    assert_eq!(
        handle.compile_cache.max_compiles_per_key(),
        1,
        "{:?}",
        handle.compile_cache.compile_counts()
    );
    assert_eq!(snap.compilations_total, handle.compile_cache.compilations());

    // the stats document surfaces the new plane
    let mut conn = BlockingConn::connect(&addr).unwrap();
    match conn.call(&Request::Stats).unwrap() {
        Response::Stats(v) => {
            assert_eq!(v.req_f64("phase2_rows_total").unwrap() as u64, clients as u64);
            assert!(v.get("batch_occupancy_mean").is_some());
            let cc = v.req("compile_cache").unwrap();
            assert_eq!(cc.req_f64("max_compiles_per_key").unwrap() as u64, 1);
        }
        other => panic!("unexpected {other:?}"),
    }
    handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// `--warm paper`: the server comes up with the likely reply keys
/// encoded and phase-2 plans built; the first real request is a cache
/// hit, not an encode.
#[test]
fn warm_cache_preloads_replies_and_plans() {
    let dir = synthetic_bundle("ep-warm");
    let handle = serve(ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers: 2,
        warm: WarmMode::Paper,
        host_fallback: true,
        artifacts_dir: dir.to_str().unwrap().to_string(),
        ..ServerConfig::default()
    })
    .unwrap();

    let warm = handle.snapshot();
    assert!(warm.warmed_total >= 1, "{warm:?}");
    assert!(handle.cache.len() >= 1, "encoded replies resident before traffic");
    assert!(handle.compile_cache.plan_len() >= 1, "phase-2 plans resident");
    let encodes_after_warm = warm.encodes_total;

    // a first client request for a warmed key re-encodes nothing
    let mut conn = BlockingConn::connect(&handle.addr.to_string()).unwrap();
    match conn.call(&Request::Infer(paper_request("tinymlp", 0.02))).unwrap() {
        Response::Segment(r) => assert!(r.session > 0),
        other => panic!("unexpected {other:?}"),
    }
    let snap = handle.snapshot();
    assert_eq!(snap.encodes_total, encodes_after_warm, "warmed key served from cache");
    assert!(snap.cache_hits > warm.cache_hits);
    handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
