//! The write-ahead overlay of the store stack.

use super::{Column, Layer, ReadLayer, WriteLayer};
use std::collections::HashMap;

/// A write-ahead overlay over any [`WriteLayer`] (calimero's `Temporal`
/// shape): `Base = L` in the [`Layer`] stack. Writes buffer in memory as
/// the *net* effect per key — a put shadows earlier puts, a delete
/// becomes a tombstone — reads answer through the overlay first, and
/// [`Temporal::commit`] applies the buffered state to the base in one
/// deterministic (key-sorted) sweep. Dropping an uncommitted overlay
/// discards it: the base never sees half a batch.
///
/// The [`StoreTier`](super::StoreTier) drains its staged cache mutations
/// through one of these per flush, so a key written five times in one
/// housekeeping window costs the segment log **one** record.
pub struct Temporal<'base, L: WriteLayer> {
    base: &'base mut L,
    /// Net staged state per column: `Some(value)` = put, `None` =
    /// tombstone (delete on commit).
    overlay: [HashMap<Vec<u8>, Option<Vec<u8>>>; Column::ALL.len()],
}

impl<'base, L: WriteLayer> Temporal<'base, L> {
    /// Open an empty overlay over `base` (see also
    /// [`LayerExt::temporal`](super::LayerExt::temporal)).
    pub fn new(base: &'base mut L) -> Temporal<'base, L> {
        Temporal { base, overlay: Default::default() }
    }

    /// Staged (uncommitted) operations across all columns.
    pub fn staged_len(&self) -> usize {
        self.overlay.iter().map(HashMap::len).sum()
    }

    /// Apply the buffered net state to the base, keys sorted per column
    /// so commit order (and therefore the log's record order) is
    /// deterministic. Consumes the overlay.
    pub fn commit(self) {
        for col in Column::ALL {
            let mut ops: Vec<(Vec<u8>, Option<Vec<u8>>)> =
                self.overlay[col.index()].clone().into_iter().collect();
            ops.sort_by(|a, b| a.0.cmp(&b.0));
            for (key, op) in ops {
                match op {
                    Some(value) => self.base.put(col, &key, &value),
                    None => self.base.delete(col, &key),
                }
            }
        }
    }
}

impl<L: WriteLayer> Layer for Temporal<'_, L> {
    type Base = L;
}

impl<L: WriteLayer> ReadLayer for Temporal<'_, L> {
    fn has(&self, col: Column, key: &[u8]) -> bool {
        match self.overlay[col.index()].get(key) {
            Some(Some(_)) => true,
            Some(None) => false, // staged tombstone shadows the base
            None => self.base.has(col, key),
        }
    }

    fn get(&self, col: Column, key: &[u8]) -> Option<Vec<u8>> {
        match self.overlay[col.index()].get(key) {
            Some(Some(v)) => Some(v.clone()),
            Some(None) => None,
            None => self.base.get(col, key),
        }
    }

    fn for_each(&self, col: Column, f: &mut dyn FnMut(&[u8], &[u8]) -> bool) {
        let overlay = &self.overlay[col.index()];
        let mut stop = false;
        for (k, v) in overlay {
            if let Some(v) = v {
                if !f(k, v) {
                    stop = true;
                    break;
                }
            }
        }
        if stop {
            return;
        }
        self.base.for_each(col, &mut |k, v| {
            if overlay.contains_key(k) {
                // shadowed: already visited (put) or tombstoned
                return true;
            }
            f(k, v)
        });
    }
}

impl<L: WriteLayer> WriteLayer for Temporal<'_, L> {
    fn put(&mut self, col: Column, key: &[u8], value: &[u8]) {
        self.overlay[col.index()].insert(key.to_vec(), Some(value.to_vec()));
    }

    fn delete(&mut self, col: Column, key: &[u8]) {
        self.overlay[col.index()].insert(key.to_vec(), None);
    }
}

#[cfg(test)]
mod tests {
    use super::super::mem::tests::exercise_layer;
    use super::super::{LayerExt, MemLayer};
    use super::*;

    #[test]
    fn temporal_satisfies_the_stack_contract() {
        let mut mem = MemLayer::new();
        let mut t = mem.temporal();
        exercise_layer(&mut t);
    }

    #[test]
    fn overlay_shadows_base_until_commit() {
        let mut mem = MemLayer::new();
        mem.put(Column::Decision, b"kept", b"base");
        mem.put(Column::Decision, b"gone", b"base");
        let mut t = mem.temporal();
        t.put(Column::Decision, b"kept", b"staged");
        t.delete(Column::Decision, b"gone");
        t.put(Column::Decision, b"new", b"fresh");
        assert_eq!(t.get(Column::Decision, b"kept"), Some(b"staged".to_vec()));
        assert_eq!(t.get(Column::Decision, b"gone"), None);
        assert!(!t.has(Column::Decision, b"gone"));
        assert_eq!(t.len(Column::Decision), 2, "tombstone excluded, new key included");
        assert_eq!(t.staged_len(), 3);
        t.commit();
        // the base now holds exactly the net state
        assert_eq!(mem.get(Column::Decision, b"kept"), Some(b"staged".to_vec()));
        assert_eq!(mem.get(Column::Decision, b"gone"), None);
        assert_eq!(mem.get(Column::Decision, b"new"), Some(b"fresh".to_vec()));
    }

    #[test]
    fn dropping_an_uncommitted_overlay_discards_it() {
        let mut mem = MemLayer::new();
        mem.put(Column::Reply, b"k", b"v");
        {
            let mut t = mem.temporal();
            t.delete(Column::Reply, b"k");
            t.put(Column::Reply, b"other", b"x");
            // dropped without commit
        }
        assert_eq!(mem.get(Column::Reply, b"k"), Some(b"v".to_vec()));
        assert!(!mem.has(Column::Reply, b"other"));
    }

    #[test]
    fn last_staged_write_per_key_wins() {
        let mut mem = MemLayer::new();
        let mut t = mem.temporal();
        t.put(Column::Plan, b"k", b"1");
        t.put(Column::Plan, b"k", b"2");
        t.delete(Column::Plan, b"k");
        t.put(Column::Plan, b"k", b"3");
        assert_eq!(t.staged_len(), 1, "net effect per key, not an op journal");
        t.commit();
        assert_eq!(mem.get(Column::Plan, b"k"), Some(b"3".to_vec()));
    }
}
