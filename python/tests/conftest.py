"""Shared fixtures: a small trained mlp6 reused across test modules."""

import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from compile import data as D  # noqa: E402
from compile import model as M  # noqa: E402
from compile import train as T  # noqa: E402


@pytest.fixture(scope="session")
def tiny_mlp6():
    """A quickly trained mlp6 (~90% on its synthetic task) shared by tests."""
    spec = M.mlp6_spec()
    x, y = D.make("digits", 1200, seed=0)
    params, history = T.train(spec, x, y, epochs=3, seed=0)
    x_te, y_te = D.make("digits", 400, seed=1)
    acc = M.accuracy(spec, params, x_te, y_te)
    return dict(spec=spec, params=params, history=history,
                x_te=x_te, y_te=y_te, acc=acc)


@pytest.fixture(scope="session")
def tiny_cnn():
    """A quickly trained edgecnn10."""
    spec = M.edgecnn_spec(10)
    x, y = D.make("cifar10_syn", 600, seed=0)
    params, history = T.train(spec, x, y, epochs=2, seed=0)
    return dict(spec=spec, params=params, history=history)
