//! Accuracy/size trade-off sweep through the public API (Fig. 6 flavor,
//! plus *measured* accuracy at each level via real quantized inference).
//!
//! ```text
//! cargo run --release --example accuracy_sweep [-- <eval_samples>]
//! ```

use qpart::prelude::*;
use std::sync::Arc;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let n_eval: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(256);
    let Ok(bundle) = Bundle::load("artifacts") else {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        return Ok(());
    };
    let bundle = Arc::new(bundle);
    let entry = bundle.model("mlp6")?.clone();
    let arch = bundle.arch("mlp6")?.clone();
    let calib = bundle.calibration("mlp6")?;
    let patterns = offline_quantize(&arch, &calib, OfflineConfig::default())?;

    let (x, y) = bundle.dataset(&entry.dataset)?;
    let x = HostTensor::from(x);
    let n = n_eval.min(x.batch());
    let xs = x.slice_rows(0, n);
    let ys = &y[..n];
    let mut ex = Executor::new(Arc::clone(&bundle))?;
    let base = ex.eval_accuracy(&xs, ys, |e, c| Ok(e.run_full("mlp6", c)?))?;
    println!("full-precision accuracy over {n} samples: {:.2}%", base * 100.0);

    println!(
        "\n{:>10} {:>14} {:>10} {:>12} {:>12} {:>12}",
        "budget", "payload(bits)", "vs f32", "predicted", "measured", "within?"
    );
    let l = arch.num_layers();
    for (k, &level) in patterns.levels.iter().enumerate() {
        let pat = patterns
            .get(qpart::core::quant::PatternKey { level_idx: k, partition: l })
            .unwrap()
            .clone();
        let payload = pat.payload_bits(&arch);
        let f32_payload = pat.payload_bits_f32(&arch);
        let acc = ex.eval_accuracy(&xs, ys, |e, c| {
            Ok(e.run_split("mlp6", &pat, c)?.logits)
        })?;
        let measured = base - acc;
        println!(
            "{:>9.2}% {:>14} {:>9.1}% {:>11.3}% {:>11.3}% {:>12}",
            level * 100.0,
            payload,
            100.0 * payload as f64 / f32_payload as f64,
            pat.predicted_degradation * 100.0,
            measured * 100.0,
            if measured <= level + 0.01 { "yes" } else { "OVER" }
        );
    }
    println!(
        "\npaper shape (Fig. 6): payload decays ~exponentially as the accuracy budget loosens."
    );
    Ok(())
}
