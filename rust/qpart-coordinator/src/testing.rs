//! PJRT-free synthetic artifact bundles and a minimal protocol client,
//! for tests and the `bench-serve` load harness.
//!
//! [`synthetic_bundle`] writes a loadable bundle (manifest + weights +
//! calibration + dataset, **zero HLO executables**) into a temp
//! directory. The coordinator's phase-1 path — Algorithm 2 decision,
//! segment quantization, bit-packing, encoded-reply caching, session
//! open — is pure Rust, and with `ServerConfig::host_fallback` phase-2
//! execution runs on the host reference kernels, so a real multi-worker
//! server can be driven through **both protocol phases** over TCP in any
//! offline environment ([`synthetic_upload`] builds the phase-2 driver's
//! uploads). Only PJRT-backed execution needs `make artifacts`.
//!
//! Helpers panic on I/O errors: they run in tests and the bench harness,
//! where a broken temp dir should abort loudly, not propagate.

use crate::service::boundary_dims;
use qpart_core::accuracy::CalibrationTable;
use qpart_core::json::Value;
use qpart_core::model::{LayerKind, LayerSpec, ModelSpec};
use qpart_core::quant::{pack_bits, quantize};
use qpart_core::tensor::{save_i32, Tensor};
use qpart_proto::frame::{read_any_frame, write_binary_frame, write_frame};
use qpart_proto::messages::{ActivationUpload, InferReply, Request, Response};
use std::io::BufReader;
use std::net::TcpStream;
use std::path::PathBuf;

/// Minimal blocking protocol connection (no PJRT-backed `DeviceClient`
/// needed): JSON requests out — or binary activation frames on demand —
/// either framing in. Shared by the coordinator's integration tests and
/// `qpart bench-serve`.
pub struct BlockingConn {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl BlockingConn {
    pub fn connect(addr: &str) -> Result<BlockingConn, String> {
        let stream = TcpStream::connect(addr).map_err(|e| e.to_string())?;
        stream.set_nodelay(true).map_err(|e| e.to_string())?;
        let writer = stream.try_clone().map_err(|e| e.to_string())?;
        Ok(BlockingConn { reader: BufReader::new(stream), writer })
    }

    /// Send one request and read one response (JSON or binary frame).
    pub fn call(&mut self, req: &Request) -> Result<Response, String> {
        write_frame(&mut self.writer, &req.to_line()).map_err(|e| e.to_string())?;
        let frame = read_any_frame(&mut self.reader).map_err(|e| e.to_string())?;
        Response::from_frame(&frame).map_err(|e| e.to_string())
    }

    /// Send one activation upload as a **binary request frame** (only
    /// valid after a granted `hello`) and read the response.
    pub fn call_binary_upload(&mut self, a: &ActivationUpload) -> Result<Response, String> {
        let (header, blob) = a.to_binary();
        write_binary_frame(&mut self.writer, &header, &blob).map_err(|e| e.to_string())?;
        let frame = read_any_frame(&mut self.reader).map_err(|e| e.to_string())?;
        Response::from_frame(&frame).map_err(|e| e.to_string())
    }
}

/// Build a valid phase-2 upload for `reply`: a deterministic synthetic
/// boundary activation of the session's expected dims, quantized at the
/// pattern's activation bit-width and bit-packed — the phase-2 driver
/// for tests and `bench-serve` (no device-side PJRT required).
pub fn synthetic_upload(reply: &InferReply, arch: &ModelSpec, seed: u64) -> ActivationUpload {
    let dims = boundary_dims(arch, reply.pattern.partition, 1);
    let n: usize = dims.iter().product();
    let mut rng = qpart_core::rng::Rng::new(seed.wrapping_add(0x5EED));
    let values: Vec<f32> = (0..n).map(|_| rng.range_f64(0.0, 1.0) as f32).collect();
    let bits = reply.pattern.activation_bits.min(16);
    let q = quantize(&values, bits).expect("synthetic activation quantizes");
    let packed = pack_bits(&q.codes, bits).expect("synthetic activation packs");
    ActivationUpload {
        session: reply.session,
        bits,
        qmin: q.params.min,
        step: q.params.step(),
        dims,
        packed,
    }
}

/// Accuracy-degradation levels the synthetic calibration covers.
pub const LEVELS: [f64; 5] = [0.0025, 0.005, 0.01, 0.02, 0.05];

fn lin(name: &str, d_in: usize, d_out: usize, relu: bool) -> LayerSpec {
    LayerSpec { name: name.into(), kind: LayerKind::Linear { d_in, d_out }, relu }
}

/// The synthetic bundle's model: a 3-layer MLP named `tinymlp`.
pub fn tiny_arch() -> ModelSpec {
    ModelSpec::new(
        "tinymlp",
        vec![lin("fc1", 256, 512, true), lin("fc2", 512, 256, true), lin("fc3", 256, 10, false)],
        10,
    )
    .unwrap()
}

/// Write a loadable synthetic bundle into a fresh per-process temp
/// directory (`qpart-synth-<pid>-<tag>`) and return its path. The caller
/// owns cleanup (`std::fs::remove_dir_all`).
pub fn synthetic_bundle(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("qpart-synth-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    for sub in ["weights/tinymlp", "calibration", "data"] {
        std::fs::create_dir_all(dir.join(sub)).unwrap();
    }
    let arch = tiny_arch();

    let mut rng = qpart_core::rng::Rng::new(7);
    for (i, layer) in arch.layers.iter().enumerate() {
        let (d_in, d_out) = match layer.kind {
            LayerKind::Linear { d_in, d_out } => (d_in, d_out),
            _ => unreachable!("tinymlp is linear-only"),
        };
        let w = Tensor::new(
            vec![d_in, d_out],
            (0..d_in * d_out).map(|_| rng.range_f64(-0.5, 0.5) as f32).collect(),
        )
        .unwrap();
        let b = Tensor::new(
            vec![d_out],
            (0..d_out).map(|_| rng.range_f64(-0.1, 0.1) as f32).collect(),
        )
        .unwrap();
        w.save(dir.join(format!("weights/tinymlp/l{}_w.qt", i + 1))).unwrap();
        b.save(dir.join(format!("weights/tinymlp/l{}_b.qt", i + 1))).unwrap();
    }

    let calib = CalibrationTable::synthetic(&arch, &LEVELS, 1);
    std::fs::write(dir.join("calibration/tinymlp.json"), calib.to_json().to_string_pretty())
        .unwrap();

    Tensor::zeros(vec![4, 256]).save(dir.join("data/synth_test_x.qt")).unwrap();
    save_i32(dir.join("data/synth_test_y.qt"), &[4], &[0, 1, 2, 3]).unwrap();

    let manifest = Value::obj([
        ("archs", Value::Arr(vec![arch.to_json()])),
        (
            "models",
            Value::Arr(vec![Value::obj([
                ("name", "tinymlp".into()),
                ("arch", "tinymlp".into()),
                ("dataset", "synth".into()),
                ("weights_dir", "weights/tinymlp".into()),
                ("calibration", "calibration/tinymlp.json".into()),
                ("test_accuracy", 0.9.into()),
            ])]),
        ),
        ("executables", Value::Arr(vec![])),
        (
            "datasets",
            Value::Arr(vec![Value::obj([
                ("name", "synth".into()),
                ("x", "data/synth_test_x.qt".into()),
                ("y", "data/synth_test_y.qt".into()),
                ("n", 4usize.into()),
                ("classes", 10usize.into()),
            ])]),
        ),
        ("levels", Value::num_arr(&LEVELS)),
    ]);
    std::fs::write(dir.join("manifest.json"), manifest.to_string_pretty()).unwrap();
    dir
}
