//! Workload generator: Poisson request arrivals over a heterogeneous
//! device fleet (the edge population of paper §I: phones, watches,
//! cameras, AR glasses — differing clock rates, energy efficiency, memory).

use qpart_core::cost::DeviceProfile;
use qpart_core::rng::Rng;

/// A class of edge devices with a characteristic profile.
#[derive(Debug, Clone)]
pub struct DeviceClass {
    pub name: &'static str,
    pub profile: DeviceProfile,
    /// Relative population weight.
    pub weight: f64,
    /// Accuracy budgets this class requests (sampled uniformly).
    pub accuracy_budgets: Vec<f64>,
}

impl DeviceClass {
    /// A representative heterogeneous fleet (see paper §I motivations).
    pub fn default_fleet() -> Vec<DeviceClass> {
        let base = DeviceProfile::paper_default();
        vec![
            DeviceClass {
                name: "phone",
                profile: DeviceProfile { clock_hz: 2e9, kappa: 1e-27, ..base },
                weight: 0.4,
                accuracy_budgets: vec![0.005, 0.01],
            },
            DeviceClass {
                name: "camera",
                profile: DeviceProfile { clock_hz: 400e6, ..base },
                weight: 0.3,
                accuracy_budgets: vec![0.01, 0.02],
            },
            DeviceClass {
                name: "watch",
                profile: DeviceProfile {
                    clock_hz: 100e6,
                    kappa: 5e-27,
                    memory_bits: 32 * 1024 * 1024 * 8,
                    ..base
                },
                weight: 0.2,
                accuracy_budgets: vec![0.02, 0.05],
            },
            DeviceClass {
                name: "sensor",
                profile: DeviceProfile {
                    clock_hz: 50e6,
                    kappa: 8e-27,
                    memory_bits: 8 * 1024 * 1024 * 8,
                    ..base
                },
                weight: 0.1,
                accuracy_budgets: vec![0.05],
            },
        ]
    }
}

/// Workload configuration.
#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    /// Mean request arrival rate (requests/s, fleet-wide Poisson).
    pub arrival_rate: f64,
    /// Number of devices.
    pub n_devices: usize,
    /// Simulation horizon (s).
    pub duration_s: f64,
    pub seed: u64,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig { arrival_rate: 20.0, n_devices: 16, duration_s: 10.0, seed: 1 }
    }
}

/// One generated request event.
#[derive(Debug, Clone)]
pub struct RequestEvent {
    pub arrival_s: f64,
    pub device: usize,
    pub accuracy_budget: f64,
}

/// Generates the fleet and the arrival sequence.
pub struct WorkloadGen {
    pub devices: Vec<(DeviceProfile, &'static str)>,
    pub device_budgets: Vec<Vec<f64>>,
    rng: Rng,
    cfg: WorkloadConfig,
}

impl WorkloadGen {
    pub fn new(cfg: WorkloadConfig, classes: &[DeviceClass]) -> WorkloadGen {
        assert!(!classes.is_empty());
        let mut rng = Rng::new(cfg.seed);
        let total_w: f64 = classes.iter().map(|c| c.weight).sum();
        let mut devices = Vec::with_capacity(cfg.n_devices);
        let mut device_budgets = Vec::with_capacity(cfg.n_devices);
        for _ in 0..cfg.n_devices {
            let mut pick = rng.uniform() * total_w;
            let mut chosen = &classes[0];
            for c in classes {
                if pick < c.weight {
                    chosen = c;
                    break;
                }
                pick -= c.weight;
            }
            devices.push((chosen.profile, chosen.name));
            device_budgets.push(chosen.accuracy_budgets.clone());
        }
        WorkloadGen { devices, device_budgets, rng, cfg }
    }

    /// Generate the full arrival sequence (sorted by time).
    pub fn events(&mut self) -> Vec<RequestEvent> {
        let mut events = Vec::new();
        let mut t = 0.0;
        loop {
            t += self.rng.exponential(1.0 / self.cfg.arrival_rate);
            if t >= self.cfg.duration_s {
                break;
            }
            let device = self.rng.range_usize(0, self.devices.len());
            let budgets = &self.device_budgets[device];
            let accuracy_budget = *self.rng.choose(budgets);
            events.push(RequestEvent { arrival_s: t, device, accuracy_budget });
        }
        events
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fleet_respects_population() {
        let cfg = WorkloadConfig { n_devices: 400, seed: 3, ..Default::default() };
        let gen = WorkloadGen::new(cfg, &DeviceClass::default_fleet());
        let phones = gen.devices.iter().filter(|(_, n)| *n == "phone").count();
        // 40% ± sampling noise
        assert!((100..220).contains(&phones), "phones={phones}");
    }

    #[test]
    fn poisson_rate_approximate() {
        let cfg = WorkloadConfig {
            arrival_rate: 50.0,
            duration_s: 20.0,
            n_devices: 4,
            seed: 5,
        };
        let mut gen = WorkloadGen::new(cfg, &DeviceClass::default_fleet());
        let events = gen.events();
        let expected = 50.0 * 20.0;
        assert!(
            (expected * 0.85..expected * 1.15).contains(&(events.len() as f64)),
            "events={}",
            events.len()
        );
        // sorted arrivals
        assert!(events.windows(2).all(|w| w[0].arrival_s <= w[1].arrival_s));
    }

    #[test]
    fn deterministic_by_seed() {
        let cfg = WorkloadConfig::default();
        let a: Vec<f64> = WorkloadGen::new(cfg.clone(), &DeviceClass::default_fleet())
            .events()
            .iter()
            .map(|e| e.arrival_s)
            .collect();
        let b: Vec<f64> = WorkloadGen::new(cfg, &DeviceClass::default_fleet())
            .events()
            .iter()
            .map(|e| e.arrival_s)
            .collect();
        assert_eq!(a, b);
    }

    #[test]
    fn budgets_match_class() {
        let cfg = WorkloadConfig { n_devices: 50, seed: 7, ..Default::default() };
        let mut gen = WorkloadGen::new(cfg, &DeviceClass::default_fleet());
        let budgets = gen.device_budgets.clone();
        for e in gen.events() {
            assert!(budgets[e.device].contains(&e.accuracy_budget));
        }
    }
}
