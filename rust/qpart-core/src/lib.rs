//! # qpart-core
//!
//! Core algorithms and models of the QPART inference-serving system
//! (Li et al., *QPART: Adaptive Model Quantization and Dynamic Workload
//! Balancing for Accuracy-aware Edge Inference*, CS.DC 2025).
//!
//! This crate is pure Rust (no PJRT, no network) and holds:
//!
//! * [`quant`] — the uniform asymmetric quantizer (paper Eq. 9–10),
//!   arbitrary-bit-width bit-packing for the simulated wire, and quantization
//!   patterns `(b, p)`.
//! * [`accuracy`] — the quantization-noise / accuracy-degradation model
//!   (Eq. 18–22) and calibration tables produced by the build-time Python
//!   calibration pass.
//! * [`model`] — layer/model descriptors with MAC and size accounting
//!   (Eq. 1–4, 14) and the built-in model zoo.
//! * [`cost`] — device/server/transmission cost models (Eq. 5–8, 24–26) and
//!   the Eq. 17 objective.
//! * [`channel`] — the wireless channel model (Eq. 11–16).
//! * [`optimizer`] — the closed-form bit-width solver (Eq. 27/40), the
//!   offline pattern-generation algorithm (paper Algorithm 1) and the online
//!   serving algorithm (paper Algorithm 2).
//! * [`json`], [`config`], [`rng`], [`tensor`], [`testing`] — first-party
//!   substrates (this build is fully offline; serde/proptest/rand are not
//!   available, so the repo carries its own).

pub mod accuracy;
pub mod channel;
pub mod config;
pub mod cost;
pub mod error;
pub mod json;
pub mod model;
pub mod optimizer;
pub mod quant;
pub mod rng;
pub mod tensor;
pub mod testing;

pub use error::{Error, Result};
