"""L2: model definitions (forward passes) that call the L1 kernels.

The three runnable models mirror the Rust descriptors in
`qpart_core::model::zoo` exactly (layer dims, strides, ReLU placement):

* ``mlp6``       — the paper's Fig. 4 six-FC MNIST classifier,
* ``edgecnn``    — the Table IV CNN (32x32x3, 10/100 classes),
* ``tinyresnet`` — runnable ImageNet stand-in (residual adds included at
  execution; they carry no parameters/MACs under the paper's Eq. 2
  accounting, matching the Rust descriptor).

Every layer has a *quantized* forward (Pallas `qlinear`/`qconv` on integer
codes) and a full-precision forward. The AOT pass lowers each per-layer
function to its own HLO so the Rust runtime can execute any partition.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from .kernels import qconv, qlinear, ref


# ---------------------------------------------------------------------------
# layer / model specs (kept in lock-step with rust/qpart-core/src/model/zoo.rs)
# ---------------------------------------------------------------------------

def _lin(name, d_in, d_out, relu):
    return dict(name=name, kind="linear", d_in=d_in, d_out=d_out, relu=relu)


def _conv(name, c_in, c_out, k, stride, in_side):
    out_side = -(-in_side // stride)  # ceil
    return dict(name=name, kind="conv2d", c_in=c_in, c_out=c_out, k=k,
                stride=stride, in_side=in_side, out_side=out_side, relu=True)


def mlp6_spec():
    dims = [784, 512, 256, 128, 64, 32, 10]
    return dict(
        name="mlp6",
        num_classes=10,
        input_shape=(784,),
        layers=[_lin(f"fc{i+1}", dims[i], dims[i + 1], relu=i < 5) for i in range(6)],
        residual={},  # no skip connections
        partition_points=list(range(7)),  # 0..=6
    )


def edgecnn_spec(num_classes=10):
    return dict(
        name=f"edgecnn{num_classes}",
        num_classes=num_classes,
        input_shape=(3, 32, 32),
        layers=[
            _conv("conv1", 3, 16, 3, 1, 32),
            _conv("conv2", 16, 32, 3, 2, 32),
            _conv("conv3", 32, 64, 3, 2, 16),
            _lin("fc1", 64 * 8 * 8, 256, relu=True),
            _lin("fc2", 256, num_classes, relu=False),
        ],
        residual={},
        partition_points=list(range(6)),  # 0..=5
    )


def tinyresnet_spec(num_classes=10):
    return dict(
        name="tinyresnet",
        num_classes=num_classes,
        input_shape=(3, 32, 32),
        layers=[
            _conv("stem", 3, 16, 3, 1, 32),
            _conv("b1c1", 16, 16, 3, 1, 32),
            _conv("b1c2", 16, 16, 3, 1, 32),
            _conv("b2c1", 16, 32, 3, 2, 32),
            _conv("b2c2", 32, 32, 3, 1, 16),
            _conv("b3c1", 32, 64, 3, 2, 16),
            _conv("b3c2", 64, 64, 3, 1, 8),
            _lin("fc", 64 * 8 * 8, num_classes, relu=False),
        ],
        # residual adds: output of layer i (1-based) += output of layer j.
        # stem/b1c1/b1c2 are all 16x32x32 -> skip 1->3;
        # b2c1(4)/b2c2(5) are 32x16x16 -> skip 4->5;
        # b3c1(6)/b3c2(7) are 64x8x8 -> skip 6->7.
        residual={3: 1, 5: 4, 7: 6},
        # Partitions are restricted to residual-block boundaries so a skip
        # never crosses the device/server split (the boundary activation is
        # the only tensor shipped uplink). Mirrored in the Rust descriptor.
        partition_points=[0, 1, 3, 5, 7, 8],
    )


SPECS = {
    "mlp6": mlp6_spec,
    "edgecnn10": lambda: edgecnn_spec(10),
    "edgecnn100": lambda: edgecnn_spec(100),
    "tinyresnet": lambda: tinyresnet_spec(10),
}


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------

def init_params(spec, seed=0):
    """He-init parameter list: [{'w': ..., 'b': ...}, ...].

    linear: w [D, G]; conv: w [C_in, k, k, C_out] (im2col layout).
    """
    rng = np.random.default_rng(seed)
    params = []
    for layer in spec["layers"]:
        if layer["kind"] == "linear":
            fan_in = layer["d_in"]
            w = rng.normal(0, np.sqrt(2.0 / fan_in), size=(layer["d_in"], layer["d_out"]))
            b = np.zeros(layer["d_out"])
        else:
            fan_in = layer["c_in"] * layer["k"] ** 2
            w = rng.normal(0, np.sqrt(2.0 / fan_in),
                           size=(layer["c_in"], layer["k"], layer["k"], layer["c_out"]))
            b = np.zeros(layer["c_out"])
        params.append(dict(w=jnp.asarray(w, jnp.float32), b=jnp.asarray(b, jnp.float32)))
    return params


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

def layer_forward(layer, p, x, use_pallas=False):
    """Full-precision forward of one layer. x is [B, ...] activation."""
    relu = layer["relu"]
    if layer["kind"] == "linear":
        if x.ndim > 2:
            x = x.reshape(x.shape[0], -1)
        if use_pallas:
            # f32 path through the same kernel: codes = w, qmin = 0, step = 1
            zero = jnp.zeros((1, 1), jnp.float32)
            one = jnp.ones((1, 1), jnp.float32)
            return qlinear(x, p["w"], zero, one, p["b"][None, :], relu=relu)
        return ref.linear_ref(x, p["w"], p["b"][None, :], relu)
    # conv
    return ref.conv_ref(x, p["w"], p["b"][None, :], relu, layer["stride"])


def layer_forward_quant(layer, codes, qmin, step, bias, x):
    """Quantized forward of one layer via the Pallas kernel.

    codes: flattened grid indices as f32 ([D,G] linear / [C*k*k, C_out] conv).
    """
    relu = layer["relu"]
    if layer["kind"] == "linear":
        if x.ndim > 2:
            x = x.reshape(x.shape[0], -1)
        return qlinear(x, codes, qmin, step, bias, relu=relu)
    return qconv(x, codes, qmin, step, bias, relu, layer["k"], layer["stride"])


def forward(spec, params, x, upto=None, use_pallas=False):
    """Forward through layers [0, upto); returns the activation (logits when
    upto is None). Residual adds applied per spec['residual']."""
    upto = len(spec["layers"]) if upto is None else upto
    acts = {0: x}
    h = x
    for i, (layer, p) in enumerate(zip(spec["layers"], params), start=1):
        if i > upto:
            break
        h = layer_forward(layer, p, h, use_pallas=use_pallas)
        src = spec["residual"].get(i)
        if src is not None:
            h = h + acts[src]
        acts[i] = h
    return h


def forward_from(spec, params, h, start):
    """Forward from layer `start`+1 to the end given the boundary activation
    `h` at `start` (the server-side segment). `start` must be one of the
    spec's ``partition_points`` so every residual source the segment needs
    (src >= start) is available."""
    assert start in spec["partition_points"], (
        f"partition {start} not allowed for {spec['name']} "
        f"(valid: {spec['partition_points']})"
    )
    acts = {start: h}
    for i in range(start + 1, len(spec["layers"]) + 1):
        layer, p = spec["layers"][i - 1], params[i - 1]
        h = layer_forward(layer, p, h)
        src = spec["residual"].get(i)
        if src is not None:
            assert src >= start, f"residual {i}<-{src} crosses partition {start}"
            h = h + acts[src]
        acts[i] = h
    return h


def accuracy(spec, params, x, y, batch=256):
    """Top-1 accuracy."""
    n = x.shape[0]
    correct = 0
    for i in range(0, n, batch):
        logits = forward(spec, params, jnp.asarray(x[i:i + batch]))
        correct += int((jnp.argmax(logits, axis=1) == jnp.asarray(y[i:i + batch])).sum())
    return correct / n
