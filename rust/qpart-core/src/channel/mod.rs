//! Wireless channel model (paper §III-D, Eq. 11–16).
//!
//! Channel gain `g = α·h` (Eq. 11) with large-scale fading `α` (path loss +
//! shadowing) and small-scale fading `h ~ Exp(1)` (frequency-dependent,
//! unit mean). Received SNR `β = π·g/σ` (Eq. 12); Shannon capacity
//! `r = B·log2(1 + β)` (Eq. 13). Transmission latency and energy for a
//! payload of `Z` bits are `T = Z/r` (Eq. 15) and `E = π·Z/r` (Eq. 16).
//!
//! The paper's Table II evaluation fixes `r = 200 Mbps`; [`Channel::fixed`]
//! reproduces that, while [`FadingChannel`] draws a fresh `h` per coherence
//! period for the dynamic experiments.

use crate::rng::Rng;

/// A (momentarily constant) wireless link.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Channel {
    /// Capacity `r` in bits/second.
    pub capacity_bps: f64,
    /// Device transmit power `π` in watts.
    pub tx_power_w: f64,
}

impl Channel {
    /// Fixed-capacity channel (Table II: 200 Mbps, π = 1 W).
    pub fn fixed(capacity_bps: f64, tx_power_w: f64) -> Channel {
        Channel { capacity_bps, tx_power_w }
    }

    /// Channel from the physical model: bandwidth `B`, gain `g`, noise `σ`,
    /// transmit power `π` (Eq. 12–13).
    pub fn from_snr(bandwidth_hz: f64, gain: f64, noise_power_w: f64, tx_power_w: f64) -> Channel {
        let snr = tx_power_w * gain / noise_power_w;
        Channel { capacity_bps: bandwidth_hz * (1.0 + snr).log2(), tx_power_w }
    }

    /// Transmission latency for `bits` (Eq. 15).
    pub fn tx_latency_s(&self, bits: u64) -> f64 {
        bits as f64 / self.capacity_bps
    }

    /// Transmission energy for `bits` (Eq. 16): `π · Z / r`.
    pub fn tx_energy_j(&self, bits: u64) -> f64 {
        self.tx_power_w * self.tx_latency_s(bits)
    }
}

/// A fading link: large-scale gain `α` fixed, small-scale `h ~ Exp(1)`
/// redrawn each coherence period (Eq. 11).
#[derive(Debug, Clone)]
pub struct FadingChannel {
    pub bandwidth_hz: f64,
    /// Large-scale fading component α.
    pub alpha: f64,
    /// Noise power σ (watts).
    pub noise_power_w: f64,
    pub tx_power_w: f64,
    rng: Rng,
}

impl FadingChannel {
    pub fn new(
        bandwidth_hz: f64,
        alpha: f64,
        noise_power_w: f64,
        tx_power_w: f64,
        seed: u64,
    ) -> FadingChannel {
        FadingChannel { bandwidth_hz, alpha, noise_power_w, tx_power_w, rng: Rng::new(seed) }
    }

    /// Draw the channel for the next coherence period.
    pub fn sample(&mut self) -> Channel {
        let h = self.rng.exponential(1.0); // unit-mean small-scale fading
        Channel::from_snr(self.bandwidth_hz, self.alpha * h, self.noise_power_w, self.tx_power_w)
    }

    /// Mean capacity over `n` samples (Monte-Carlo; used by planning when a
    /// request reports only long-term statistics).
    pub fn mean_capacity_bps(&mut self, n: usize) -> f64 {
        let total: f64 = (0..n).map(|_| self.sample().capacity_bps).sum();
        total / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::assert_close;

    #[test]
    fn fixed_latency_energy_eq15_eq16() {
        // Table II: 200 Mbps, 1 W. 1 Mbit → 5 ms, 5 mJ.
        let ch = Channel::fixed(200e6, 1.0);
        assert_close(ch.tx_latency_s(1_000_000), 0.005, 1e-12, 1e-12);
        assert_close(ch.tx_energy_j(1_000_000), 0.005, 1e-12, 1e-12);
    }

    #[test]
    fn shannon_capacity_eq13() {
        // B=1 MHz, SNR = 3 → r = B·log2(4) = 2 Mbps
        let ch = Channel::from_snr(1e6, 3.0, 1.0, 1.0);
        assert_close(ch.capacity_bps, 2e6, 1e-6, 1e-12);
    }

    #[test]
    fn capacity_monotone_in_snr() {
        let lo = Channel::from_snr(1e6, 1.0, 1.0, 1.0);
        let hi = Channel::from_snr(1e6, 10.0, 1.0, 1.0);
        assert!(hi.capacity_bps > lo.capacity_bps);
    }

    #[test]
    fn fading_unit_mean_gain() {
        let mut f = FadingChannel::new(1e6, 2.0, 1.0, 1.0, 42);
        let n = 40_000;
        let mean_h: f64 =
            (0..n).map(|_| f.rng.exponential(1.0)).sum::<f64>() / n as f64;
        assert!((mean_h - 1.0).abs() < 0.02, "mean_h={mean_h}");
    }

    #[test]
    fn fading_samples_vary_deterministically() {
        let mut a = FadingChannel::new(1e6, 1.0, 1.0, 1.0, 7);
        let mut b = FadingChannel::new(1e6, 1.0, 1.0, 1.0, 7);
        let sa: Vec<f64> = (0..5).map(|_| a.sample().capacity_bps).collect();
        let sb: Vec<f64> = (0..5).map(|_| b.sample().capacity_bps).collect();
        assert_eq!(sa, sb);
        assert!(sa.windows(2).any(|w| w[0] != w[1]), "fading should vary");
    }

    #[test]
    fn mean_capacity_below_awgn_capacity() {
        // Jensen: E[log2(1+SNR·h)] ≤ log2(1+SNR·E[h])
        let mut f = FadingChannel::new(1e6, 5.0, 1.0, 1.0, 9);
        let mean = f.mean_capacity_bps(20_000);
        let awgn = Channel::from_snr(1e6, 5.0, 1.0, 1.0).capacity_bps;
        assert!(mean < awgn, "mean={mean} awgn={awgn}");
        assert!(mean > 0.5 * awgn);
    }
}
