"""Calibration tests: the noise model's empirical basis."""

import numpy as np
import pytest

from compile import calibrate as C
from compile import model as M


def test_quantize_array_roundtrip_error():
    rng = np.random.default_rng(0)
    a = rng.normal(size=(100,)).astype(np.float32)
    for bits in (2, 4, 8):
        deq, codes, qmin, step = C.quantize_array(a, bits)
        assert codes.min() >= 0 and codes.max() <= 2**bits - 1
        assert np.abs(deq - a).max() <= step / 2 + 1e-6


def test_quantize_array_constant():
    deq, _, _, step = C.quantize_array(np.full((8,), 2.5, np.float32), 4)
    assert step > 0
    np.testing.assert_allclose(deq, 2.5, atol=1e-4)


def test_noise_energy_scaling(tiny_mlp6):
    """The Eq. 18 model: quantizing at b+2 bits cuts output-noise energy by
    roughly 4^2 (the whole premise of s·4^{-b})."""
    spec, params = tiny_mlp6["spec"], tiny_mlp6["params"]
    x = tiny_mlp6["x_te"][:128]
    base = C._logits(spec, params, x)
    e = {}
    for bits in (4, 8):
        q = C._quantize_layer_params(params, 1, bits)
        e[bits] = C._out_energy(base, C._logits(spec, q, x))
    ratio = e[4] / max(e[8], 1e-12)
    assert 30 < ratio < 2000, f"expected ≈256, got {ratio}"


def test_measure_s_positive(tiny_mlp6):
    spec, params = tiny_mlp6["spec"], tiny_mlp6["params"]
    x = tiny_mlp6["x_te"][:96]
    s1 = C.measure_s_weight(spec, params, x, 1)
    s_act = C.measure_s_activation(spec, params, x, 3)
    assert s1 > 0 and s_act > 0


def test_rho_monotone_in_level(tiny_mlp6):
    spec, params = tiny_mlp6["spec"], tiny_mlp6["params"]
    x, y = tiny_mlp6["x_te"][:192], tiny_mlp6["y_te"][:192]
    levels = [0.01, 0.03, 0.08]
    rhos, base_acc = C.measure_rho(spec, params, x, y, 2, levels, "weight",
                                   iters=6, draws=1, seed=0)
    assert base_acc > 0.5
    assert all(r > 0 for r in rhos)
    assert rhos[0] <= rhos[1] <= rhos[2], rhos


def test_adversarial_energy_positive(tiny_mlp6):
    adv = C.adversarial_energy(tiny_mlp6["spec"], tiny_mlp6["params"],
                               tiny_mlp6["x_te"][:64])
    assert adv > 0


def test_full_calibration_schema(tiny_mlp6):
    spec, params = tiny_mlp6["spec"], tiny_mlp6["params"]
    x, y = tiny_mlp6["x_te"][:128], tiny_mlp6["y_te"][:128]
    cal = C.calibrate(spec, params, x, y, levels=[0.01, 0.05], seed=0)
    assert cal["model"] == "mlp6"
    assert len(cal["weight"]) == 6
    assert len(cal["activation"]) == 7
    for entry in cal["weight"] + cal["activation"]:
        assert entry["s"] > 0
        assert len(entry["rho"]) == 2
        assert all(r > 0 for r in entry["rho"])
